//! Executors over a compiled [`OperatorProgram`].
//!
//! * [`execute_dof`] — the benchmark-engine pass (eqs. 7–9) running on one
//!   contiguous slab with statically assigned buffer slots: no arena
//!   lookups, no per-node allocation, no runtime liveness bookkeeping. The
//!   arithmetic replicates the reference interpreter
//!   (`DofEngine::compute_with_arena`) operation for operation, in the same
//!   order, so results — values, `L[φ]`, FLOP counts, peak tangent bytes —
//!   are identical (asserted by `rust/tests/plan_equivalence.rs`).
//! * [`execute_tape`] — the training-tape pass: same schedule, but every
//!   node tuple is retained as an owned tensor for the reverse sweep
//!   (`dof_backward_tape`), and the tangent width is the full rank `r`
//!   (tape programs are compiled with sparsity off).
//!
//! Zeroing discipline: the slab is *not* cleared between calls (slots are
//! reused within and across calls), so every step either fully overwrites
//! its destination or explicitly zero-fills accumulation targets first —
//! the same contract the arena's scratch buffers had.

use std::ops::Range;

use crate::autodiff::dof::DofResult;
use crate::autodiff::dof_tape::DofTape;
use crate::autodiff::forward_jacobian::{seed_input, TangentBatch};
use crate::autodiff::Cost;
use crate::graph::{Graph, Op};
use crate::linalg::LdlDecomposition;
use crate::tensor::{matmul_nt, matmul_nt_into, Tensor};

use super::{NodePlan, OperatorProgram, StepKind};

// ---- slab addressing -----------------------------------------------------

fn v_rng(np: &NodePlan, batch: usize) -> Range<usize> {
    let lo = np.slot * batch;
    lo..lo + batch * np.dim
}

fn s_rng(np: &NodePlan, batch: usize) -> Range<usize> {
    let lo = (np.slot + np.dim) * batch;
    lo..lo + batch * np.dim
}

fn g_rng(np: &NodePlan, batch: usize) -> Range<usize> {
    let lo = (np.slot + 2 * np.dim) * batch;
    lo..lo + batch * np.t() * np.dim
}

fn node_rng(np: &NodePlan, batch: usize) -> Range<usize> {
    let lo = np.slot * batch;
    lo..lo + (np.t() + 2) * np.dim * batch
}

fn scratch_rng(np: &NodePlan, batch: usize) -> Range<usize> {
    let lo = np.scratch * batch;
    lo..lo + np.scratch_len * batch
}

/// Split the slab around the write window `w`: `(prefix, window, suffix)`.
fn split3<'a>(slab: &'a mut [f64], w: &Range<usize>) -> (&'a [f64], &'a mut [f64], &'a [f64]) {
    let (pre, rest) = slab.split_at_mut(w.start);
    let (win, post) = rest.split_at_mut(w.end - w.start);
    (&*pre, win, &*post)
}

/// Read a slab range that the layout guarantees is disjoint from the write
/// window `w` (addresses are absolute slab offsets).
fn rd<'a>(pre: &'a [f64], post: &'a [f64], w: &Range<usize>, r: Range<usize>) -> &'a [f64] {
    if r.end <= w.start {
        &pre[r]
    } else {
        debug_assert!(r.start >= w.end, "overlapping slab access");
        &post[r.start - w.end..r.end - w.end]
    }
}

/// Row `kk` of parent `pi`'s union-aligned tangent inside the Mul scratch.
fn aligned_row(
    aligned: &[f64],
    batch: usize,
    t: usize,
    d: usize,
    pi: usize,
    b: usize,
    kk: usize,
) -> &[f64] {
    let o = pi * batch * t * d + (b * t + kk) * d;
    &aligned[o..o + d]
}

// ---- the planned DOF pass ------------------------------------------------

/// Execute the compiled program on `x: [batch, N]`, using `slab` as the
/// only tangent storage (grown on first use, reused verbatim afterwards —
/// steady-state executions perform no heap allocation beyond the returned
/// result tensors).
pub fn execute_dof(
    program: &OperatorProgram,
    graph: &Graph,
    ldl: &LdlDecomposition,
    b_coef: Option<&[f64]>,
    c_coef: Option<f64>,
    x: &Tensor,
    slab: &mut Vec<f64>,
) -> DofResult {
    assert_eq!(x.rank(), 2, "input must be [batch, N]");
    let batch = x.dims()[0];
    assert_eq!(x.dims()[1], program.input_dim(), "input dim mismatch");
    assert_eq!(ldl.rank(), program.rank(), "program/operator rank mismatch");
    assert_eq!(graph.len(), program.node_count(), "program/graph mismatch");
    assert_eq!(
        program.options().lower_order_c,
        c_coef.is_some(),
        "program compiled with different lower-order options"
    );
    let need = program.slab_len(batch);
    if slab.len() < need {
        slab.resize(need, 0.0);
    }
    let slab = &mut slab[..need];

    for step in program.steps() {
        match &step.kind {
            StepKind::Input { in_off } => {
                input_step(program, ldl, b_coef, x, batch, slab, step.node, *in_off)
            }
            StepKind::Linear { fused_act } => {
                linear_step(program, graph, batch, slab, step.node);
                if let Some(a) = fused_act {
                    activation_step(program, graph, ldl, batch, slab, *a);
                }
            }
            StepKind::Activation => activation_step(program, graph, ldl, batch, slab, step.node),
            StepKind::Slice => slice_step(program, graph, batch, slab, step.node),
            StepKind::Add => add_step(program, graph, batch, slab, step.node),
            StepKind::Mul => mul_step(program, graph, ldl, batch, slab, step.node),
            StepKind::SumReduce => sum_reduce_step(program, graph, batch, slab, step.node),
            StepKind::Concat => concat_step(program, graph, batch, slab, step.node),
        }
    }

    // Extract the output tuple into owned tensors.
    let np = program.node_plan(program.output());
    let d = np.dim;
    let t = np.t();
    let values = Tensor::from_vec(&[batch, d], slab[v_rng(np, batch)].to_vec());
    let mut op_vals = Tensor::from_vec(&[batch, d], slab[s_rng(np, batch)].to_vec());
    let out_tangent = TangentBatch {
        data: Tensor::from_vec(&[batch * t, d], slab[g_rng(np, batch)].to_vec()),
        batch,
        t,
    };
    if let Some(c) = c_coef {
        for b in 0..batch {
            for o in 0..d {
                op_vals.set(b, o, op_vals.at(b, o) + c * values.at(b, o));
            }
        }
    }
    DofResult {
        values,
        out_tangent,
        out_active: np.active.clone(),
        operator_values: op_vals,
        cost: program.cost(batch),
        peak_tangent_bytes: program.peak_tangent_bytes(batch),
    }
}

#[allow(clippy::too_many_arguments)]
fn input_step(
    program: &OperatorProgram,
    ldl: &LdlDecomposition,
    b_coef: Option<&[f64]>,
    x: &Tensor,
    batch: usize,
    slab: &mut [f64],
    id: usize,
    in_off: usize,
) {
    let np = program.node_plan(id);
    let d = np.dim;
    let t = np.t();
    let w = node_rng(np, batch);
    let (_pre, win, _post) = split3(slab, &w);
    let s_rel = batch * d;
    let g_rel = 2 * batch * d;
    for b in 0..batch {
        win[b * d..(b + 1) * d].copy_from_slice(&x.row(b)[in_off..in_off + d]);
    }
    match b_coef {
        Some(bv) => {
            for b in 0..batch {
                win[s_rel + b * d..s_rel + (b + 1) * d]
                    .copy_from_slice(&bv[in_off..in_off + d]);
            }
        }
        None => win[s_rel..s_rel + batch * d].fill(0.0),
    }
    for b in 0..batch {
        for (kk, &k) in np.active.iter().enumerate() {
            let o = g_rel + (b * t + kk) * d;
            win[o..o + d].copy_from_slice(&ldl.l.row(k)[in_off..in_off + d]);
        }
    }
}

fn linear_step(program: &OperatorProgram, graph: &Graph, batch: usize, slab: &mut [f64], id: usize) {
    let node = graph.node(id);
    let (weight, bias) = match &node.op {
        Op::Linear { weight, bias } => (weight, bias),
        _ => unreachable!("linear step on non-linear node"),
    };
    let p = node.inputs[0];
    let np = program.node_plan(id);
    let pp = program.node_plan(p);
    let (out_d, in_d) = (weight.dims()[0], weight.dims()[1]);
    let t = pp.t();
    debug_assert_eq!(np.t(), t);
    let rows = batch * (t + 2);
    let sc = scratch_rng(np, batch);
    let stacked = sc.start..sc.start + rows * in_d;
    let gout = stacked.end..stacked.end + rows * out_d;
    debug_assert_eq!(gout.end, sc.end);

    // Phase 1: stack [v; s; G] of the parent — one GEMM serves all three
    // streams (one Wᵀ pass, full micro-kernel utilization).
    {
        let (pre, win, post) = split3(slab, &stacked);
        win[..batch * in_d].copy_from_slice(rd(pre, post, &stacked, v_rng(pp, batch)));
        win[batch * in_d..2 * batch * in_d]
            .copy_from_slice(rd(pre, post, &stacked, s_rng(pp, batch)));
        win[2 * batch * in_d..].copy_from_slice(rd(pre, post, &stacked, g_rng(pp, batch)));
    }
    // Phase 2: accumulate the GEMM into zeroed scratch.
    {
        let (pre, win, post) = split3(slab, &gout);
        win.fill(0.0);
        let a = rd(pre, post, &gout, stacked.clone());
        matmul_nt_into(a, weight.data(), win, rows, in_d, out_d);
    }
    // Phase 3: scatter into the node's slots; bias on the value stream.
    {
        let w = node_rng(np, batch);
        let (pre, win, post) = split3(slab, &w);
        let od = rd(pre, post, &w, gout);
        win[..batch * out_d].copy_from_slice(&od[..batch * out_d]);
        win[batch * out_d..2 * batch * out_d]
            .copy_from_slice(&od[batch * out_d..2 * batch * out_d]);
        win[2 * batch * out_d..].copy_from_slice(&od[2 * batch * out_d..]);
        for b in 0..batch {
            for (o, &bi) in win[b * out_d..(b + 1) * out_d].iter_mut().zip(bias.iter()) {
                *o += bi;
            }
        }
    }
}

fn activation_step(
    program: &OperatorProgram,
    graph: &Graph,
    ldl: &LdlDecomposition,
    batch: usize,
    slab: &mut [f64],
    id: usize,
) {
    let node = graph.node(id);
    let act = match &node.op {
        Op::Activation { act } => *act,
        _ => unreachable!("activation step on non-activation node"),
    };
    let p = node.inputs[0];
    let np = program.node_plan(id);
    let pp = program.node_plan(p);
    let d = np.dim;
    let t = np.t();
    let signs = &ldl.d;
    let w = node_rng(np, batch);
    let (pre, win, post) = split3(slab, &w);
    let h = rd(pre, post, &w, v_rng(pp, batch));
    let ps = rd(pre, post, &w, s_rng(pp, batch));
    let pg = rd(pre, post, &w, g_rng(pp, batch));
    let s_rel = batch * d;
    let g_rel = 2 * batch * d;
    // Value stream: σ(h), whole-buffer sweep (matches the interpreter).
    for (dst, &src) in win[..batch * d].iter_mut().zip(h.iter()) {
        *dst = act.f(src);
    }
    // Fused tangent pass: read g once, accumulate the signed square into
    // quad and write the σ'-scaled value.
    let mut df = vec![0.0; d];
    let mut quad = vec![0.0; d];
    for b in 0..batch {
        let hrow = &h[b * d..(b + 1) * d];
        for (dv, &hv) in df.iter_mut().zip(hrow.iter()) {
            *dv = act.df(hv);
        }
        quad.iter_mut().for_each(|q| *q = 0.0);
        for (kk, &k) in np.active.iter().enumerate() {
            let sign = signs[k];
            let src = &pg[(b * t + kk) * d..(b * t + kk + 1) * d];
            let o = g_rel + (b * t + kk) * d;
            let dst = &mut win[o..o + d];
            for c in 0..d {
                let gv = src[c];
                quad[c] += sign * gv * gv;
                dst[c] = df[c] * gv;
            }
        }
        let psr = &ps[b * d..(b + 1) * d];
        let sp = &mut win[s_rel + b * d..s_rel + (b + 1) * d];
        for c in 0..d {
            sp[c] = act.d2f(hrow[c]) * quad[c] + df[c] * psr[c];
        }
    }
}

fn slice_step(program: &OperatorProgram, graph: &Graph, batch: usize, slab: &mut [f64], id: usize) {
    let node = graph.node(id);
    let (start, len) = match &node.op {
        Op::Slice { start, len } => (*start, *len),
        _ => unreachable!("slice step on non-slice node"),
    };
    let p = node.inputs[0];
    let np = program.node_plan(id);
    let pp = program.node_plan(p);
    let pd = pp.dim;
    let tp = pp.t();
    let t = np.t();
    let w = node_rng(np, batch);
    let (pre, win, post) = split3(slab, &w);
    let pv = rd(pre, post, &w, v_rng(pp, batch));
    let psl = rd(pre, post, &w, s_rng(pp, batch));
    let pg = rd(pre, post, &w, g_rng(pp, batch));
    let s_rel = batch * len;
    let g_rel = 2 * batch * len;
    for b in 0..batch {
        win[b * len..(b + 1) * len]
            .copy_from_slice(&pv[b * pd + start..b * pd + start + len]);
        win[s_rel + b * len..s_rel + (b + 1) * len]
            .copy_from_slice(&psl[b * pd + start..b * pd + start + len]);
    }
    // Only the rows the compile-time compaction kept are copied; rows that
    // are structurally zero inside the slice window were pruned at compile.
    for b in 0..batch {
        for (nk, &kk) in np.keep.iter().enumerate() {
            let src = &pg[(b * tp + kk) * pd + start..(b * tp + kk) * pd + start + len];
            let o = g_rel + (b * t + nk) * len;
            win[o..o + len].copy_from_slice(src);
        }
    }
}

fn add_step(program: &OperatorProgram, graph: &Graph, batch: usize, slab: &mut [f64], id: usize) {
    let node = graph.node(id);
    let np = program.node_plan(id);
    let d = np.dim;
    let t = np.t();
    let w = node_rng(np, batch);
    let (pre, win, post) = split3(slab, &w);
    let s_rel = batch * d;
    let g_rel = 2 * batch * d;
    for (pi, &p) in node.inputs.iter().enumerate() {
        let pp = program.node_plan(p);
        let pv = rd(pre, post, &w, v_rng(pp, batch));
        let psl = rd(pre, post, &w, s_rng(pp, batch));
        if pi == 0 {
            win[..batch * d].copy_from_slice(pv);
            win[s_rel..s_rel + batch * d].copy_from_slice(psl);
        } else {
            for (dst, &sv) in win[..batch * d].iter_mut().zip(pv.iter()) {
                *dst += sv;
            }
            for (dst, &sv) in win[s_rel..s_rel + batch * d].iter_mut().zip(psl.iter()) {
                *dst += sv;
            }
        }
    }
    // Union-aligned tangent sum: zero, then accumulate each parent's rows
    // at their precomputed union positions.
    win[g_rel..g_rel + batch * t * d].fill(0.0);
    for (pi, &p) in node.inputs.iter().enumerate() {
        let pp = program.node_plan(p);
        let tp = pp.t();
        let pg = rd(pre, post, &w, g_rng(pp, batch));
        let pos = &np.parent_pos[pi];
        for b in 0..batch {
            for (kk, &u) in pos.iter().enumerate() {
                let src = &pg[(b * tp + kk) * d..(b * tp + kk + 1) * d];
                let o = g_rel + (b * t + u) * d;
                let dst = &mut win[o..o + d];
                for c in 0..d {
                    dst[c] += src[c];
                }
            }
        }
    }
}

fn concat_step(program: &OperatorProgram, graph: &Graph, batch: usize, slab: &mut [f64], id: usize) {
    let node = graph.node(id);
    let np = program.node_plan(id);
    let d = np.dim;
    let t = np.t();
    let w = node_rng(np, batch);
    let (pre, win, post) = split3(slab, &w);
    let s_rel = batch * d;
    let g_rel = 2 * batch * d;
    let mut off = 0usize;
    for &p in &node.inputs {
        let pp = program.node_plan(p);
        let pd = pp.dim;
        let pv = rd(pre, post, &w, v_rng(pp, batch));
        let psl = rd(pre, post, &w, s_rng(pp, batch));
        for b in 0..batch {
            win[b * d + off..b * d + off + pd].copy_from_slice(&pv[b * pd..(b + 1) * pd]);
            win[s_rel + b * d + off..s_rel + b * d + off + pd]
                .copy_from_slice(&psl[b * pd..(b + 1) * pd]);
        }
        off += pd;
    }
    win[g_rel..g_rel + batch * t * d].fill(0.0);
    let mut off = 0usize;
    for (pi, &p) in node.inputs.iter().enumerate() {
        let pp = program.node_plan(p);
        let pd = pp.dim;
        let tp = pp.t();
        let pg = rd(pre, post, &w, g_rng(pp, batch));
        let pos = &np.parent_pos[pi];
        for b in 0..batch {
            for (kk, &u) in pos.iter().enumerate() {
                let src = &pg[(b * tp + kk) * pd..(b * tp + kk + 1) * pd];
                let o = g_rel + (b * t + u) * d + off;
                win[o..o + pd].copy_from_slice(src);
            }
        }
        off += pd;
    }
}

fn mul_step(
    program: &OperatorProgram,
    graph: &Graph,
    ldl: &LdlDecomposition,
    batch: usize,
    slab: &mut [f64],
    id: usize,
) {
    let node = graph.node(id);
    let np = program.node_plan(id);
    let d = np.dim;
    let t = np.t();
    let k = node.inputs.len();
    let signs = &ldl.d;

    // Phase 1: materialize every parent's union-aligned tangent in the step
    // scratch (zero-filled missing rows) — the `expand_to` of the
    // interpreter, but into preassigned storage.
    let sc = scratch_rng(np, batch);
    {
        let (pre, win, post) = split3(slab, &sc);
        win.fill(0.0);
        for (pi, &p) in node.inputs.iter().enumerate() {
            let pp = program.node_plan(p);
            let tp = pp.t();
            let pg = rd(pre, post, &sc, g_rng(pp, batch));
            let pos = &np.parent_pos[pi];
            let block = pi * batch * t * d;
            for b in 0..batch {
                for (kk, &u) in pos.iter().enumerate() {
                    let src = &pg[(b * tp + kk) * d..(b * tp + kk + 1) * d];
                    let o = block + (b * t + u) * d;
                    win[o..o + d].copy_from_slice(src);
                }
            }
        }
    }

    // Phase 2: the eq. 9 product rule over the aligned tangents.
    let w = node_rng(np, batch);
    let (pre, win, post) = split3(slab, &w);
    let s_rel = batch * d;
    let g_rel = 2 * batch * d;
    {
        let p0 = program.node_plan(node.inputs[0]);
        let pv0 = rd(pre, post, &w, v_rng(p0, batch));
        win[..batch * d].copy_from_slice(pv0);
    }
    for &p in &node.inputs[1..] {
        let pp = program.node_plan(p);
        let pv = rd(pre, post, &w, v_rng(pp, batch));
        for (dst, &sv) in win[..batch * d].iter_mut().zip(pv.iter()) {
            *dst *= sv;
        }
    }
    win[s_rel..s_rel + batch * d].fill(0.0);
    win[g_rel..g_rel + batch * t * d].fill(0.0);

    let pvals: Vec<&[f64]> = node
        .inputs
        .iter()
        .map(|&p| rd(pre, post, &w, v_rng(program.node_plan(p), batch)))
        .collect();
    let psums: Vec<&[f64]> = node
        .inputs
        .iter()
        .map(|&p| rd(pre, post, &w, s_rng(program.node_plan(p), batch)))
        .collect();
    let aligned = rd(pre, post, &w, sc.clone());

    let mut coef = vec![1.0; d];
    let mut coef2 = vec![1.0; d];
    let mut cross = vec![0.0; d];
    for b in 0..batch {
        for pi in 0..k {
            coef.iter_mut().for_each(|c| *c = 1.0);
            for (qi, pv) in pvals.iter().enumerate() {
                if qi != pi {
                    for (c, &xv) in coef.iter_mut().zip(&pv[b * d..(b + 1) * d]) {
                        *c *= xv;
                    }
                }
            }
            for kk in 0..t {
                let src = aligned_row(aligned, batch, t, d, pi, b, kk);
                let o = g_rel + (b * t + kk) * d;
                let dst = &mut win[o..o + d];
                for c in 0..d {
                    dst[c] += coef[c] * src[c];
                }
            }
            {
                let psr = &psums[pi][b * d..(b + 1) * d];
                let srow = &mut win[s_rel + b * d..s_rel + (b + 1) * d];
                for c in 0..d {
                    srow[c] += coef[c] * psr[c];
                }
            }
            for qi in (pi + 1)..k {
                coef2.iter_mut().for_each(|c| *c = 1.0);
                for (ri, pv) in pvals.iter().enumerate() {
                    if ri != pi && ri != qi {
                        for (c, &xv) in coef2.iter_mut().zip(&pv[b * d..(b + 1) * d]) {
                            *c *= xv;
                        }
                    }
                }
                cross.iter_mut().for_each(|c| *c = 0.0);
                for (kk, &kglob) in np.active.iter().enumerate() {
                    let sign = signs[kglob];
                    let gp = aligned_row(aligned, batch, t, d, pi, b, kk);
                    let gq = aligned_row(aligned, batch, t, d, qi, b, kk);
                    for c in 0..d {
                        cross[c] += sign * gp[c] * gq[c];
                    }
                }
                let srow = &mut win[s_rel + b * d..s_rel + (b + 1) * d];
                for c in 0..d {
                    srow[c] += 2.0 * coef2[c] * cross[c];
                }
            }
        }
    }
}

fn sum_reduce_step(
    program: &OperatorProgram,
    graph: &Graph,
    batch: usize,
    slab: &mut [f64],
    id: usize,
) {
    let node = graph.node(id);
    let p = node.inputs[0];
    let np = program.node_plan(id);
    let pp = program.node_plan(p);
    let pd = pp.dim;
    let t = np.t();
    let w = node_rng(np, batch);
    let (pre, win, post) = split3(slab, &w);
    let pv = rd(pre, post, &w, v_rng(pp, batch));
    let psl = rd(pre, post, &w, s_rng(pp, batch));
    let pg = rd(pre, post, &w, g_rng(pp, batch));
    let s_rel = batch; // node dim is 1
    let g_rel = 2 * batch;
    for b in 0..batch {
        win[b] = pv[b * pd..(b + 1) * pd].iter().sum::<f64>();
        win[s_rel + b] = psl[b * pd..(b + 1) * pd].iter().sum::<f64>();
    }
    for row in 0..batch * t {
        win[g_rel + row] = pg[row * pd..(row + 1) * pd].iter().sum::<f64>();
    }
}

// ---- the planned training tape -------------------------------------------

/// Forward DOF pass over the program schedule that retains every node
/// tuple as owned tensors — the input to [`crate::autodiff::dof_tape`]'s
/// reverse sweep. Requires a program compiled with `sparsity: false` (the
/// tape always carries the full rank-`r` tangent, like the pre-plan
/// implementation).
pub fn execute_tape(
    program: &OperatorProgram,
    graph: &Graph,
    ldl: &LdlDecomposition,
    b_coef: Option<&[f64]>,
    x: &Tensor,
) -> DofTape {
    assert!(
        !program.options().sparsity,
        "tape programs are compiled dense (full tangent width)"
    );
    assert_eq!(graph.len(), program.node_count(), "program/graph mismatch");
    let n = graph.input_dim();
    assert_eq!(ldl.n, n);
    let batch = x.dims()[0];
    let r = ldl.rank();
    let mut cost = Cost::zero();
    let mut values: Vec<Tensor> = Vec::with_capacity(graph.len());
    let mut tangents: Vec<TangentBatch> = Vec::with_capacity(graph.len());
    let mut scalars: Vec<Tensor> = Vec::with_capacity(graph.len());

    for step in program.steps() {
        tape_node(
            graph,
            ldl,
            b_coef,
            x,
            batch,
            r,
            step.node,
            &step.kind,
            &mut values,
            &mut tangents,
            &mut scalars,
            &mut cost,
        );
        if let StepKind::Linear { fused_act: Some(a) } = &step.kind {
            tape_node(
                graph,
                ldl,
                b_coef,
                x,
                batch,
                r,
                *a,
                &StepKind::Activation,
                &mut values,
                &mut tangents,
                &mut scalars,
                &mut cost,
            );
        }
    }

    DofTape {
        values,
        tangents,
        scalars,
        batch,
        r,
        cost,
    }
}

/// One node of the retained-tape pass (numerically identical to the
/// pre-plan `dof_forward_tape` body).
#[allow(clippy::too_many_arguments)]
fn tape_node(
    graph: &Graph,
    ldl: &LdlDecomposition,
    b_coef: Option<&[f64]>,
    x: &Tensor,
    batch: usize,
    r: usize,
    id: usize,
    kind: &StepKind,
    values: &mut Vec<Tensor>,
    tangents: &mut Vec<TangentBatch>,
    scalars: &mut Vec<Tensor>,
    cost: &mut Cost,
) {
    debug_assert_eq!(values.len(), id, "tape must fill nodes in graph order");
    let node = graph.node(id);
    let (v, g, s) = match &node.op {
        Op::Input { dim } => {
            let in_off = match kind {
                StepKind::Input { in_off } => *in_off,
                _ => unreachable!("input node scheduled as non-input step"),
            };
            let mut v = Tensor::zeros(&[batch, *dim]);
            for b in 0..batch {
                v.row_mut(b).copy_from_slice(&x.row(b)[in_off..in_off + dim]);
            }
            let g = seed_input(&ldl.l, in_off, *dim, batch);
            let mut s = Tensor::zeros(&[batch, *dim]);
            if let Some(bv) = b_coef {
                for b in 0..batch {
                    s.row_mut(b).copy_from_slice(&bv[in_off..in_off + dim]);
                }
            }
            (v, g, s)
        }
        Op::Linear { weight, bias } => {
            let p = node.inputs[0];
            let mut v = matmul_nt(&values[p], weight);
            for b in 0..batch {
                for (o, &bi) in v.row_mut(b).iter_mut().zip(bias.iter()) {
                    *o += bi;
                }
            }
            let g = TangentBatch {
                data: matmul_nt(&tangents[p].data, weight),
                batch,
                t: r,
            };
            let s = matmul_nt(&scalars[p], weight);
            let (out_d, in_d) = (weight.dims()[0], weight.dims()[1]);
            cost.muls += ((batch * (r + 2)) * out_d * in_d) as u64;
            (v, g, s)
        }
        Op::Activation { act } => {
            let p = node.inputs[0];
            let h = &values[p];
            let d = node.dim;
            let v = h.map(|xv| act.f(xv));
            let mut g = tangents[p].clone();
            let mut s = Tensor::zeros(&[batch, d]);
            for b in 0..batch {
                let hrow = h.row(b);
                let df: Vec<f64> = hrow.iter().map(|&xv| act.df(xv)).collect();
                let d2f: Vec<f64> = hrow.iter().map(|&xv| act.d2f(xv)).collect();
                let mut quad = vec![0.0; d];
                for k in 0..r {
                    let sign = ldl.d[k];
                    let row = tangents[p].row(b, k);
                    for c in 0..d {
                        quad[c] += sign * row[c] * row[c];
                    }
                }
                for k in 0..r {
                    let row = g.row_mut(b, k);
                    for c in 0..d {
                        row[c] *= df[c];
                    }
                }
                let sp = s.row_mut(b);
                let psr = scalars[p].row(b);
                for c in 0..d {
                    sp[c] = d2f[c] * quad[c] + df[c] * psr[c];
                }
            }
            cost.muls += (batch * d * (2 * r + 2)) as u64;
            (v, g, s)
        }
        Op::Slice { start, len } => {
            let p = node.inputs[0];
            let mut v = Tensor::zeros(&[batch, *len]);
            let mut s = Tensor::zeros(&[batch, *len]);
            for b in 0..batch {
                v.row_mut(b)
                    .copy_from_slice(&values[p].row(b)[*start..*start + *len]);
                s.row_mut(b)
                    .copy_from_slice(&scalars[p].row(b)[*start..*start + *len]);
            }
            let mut g = TangentBatch::zeros(batch, r, *len);
            for row in 0..batch * r {
                g.data
                    .row_mut(row)
                    .copy_from_slice(&tangents[p].data.row(row)[*start..*start + *len]);
            }
            (v, g, s)
        }
        Op::Add => {
            let p0 = node.inputs[0];
            let mut v = values[p0].clone();
            let mut gd = tangents[p0].data.clone();
            let mut s = scalars[p0].clone();
            for &p in &node.inputs[1..] {
                v = v.add(&values[p]);
                gd = gd.add(&tangents[p].data);
                s = s.add(&scalars[p]);
            }
            (v, TangentBatch { data: gd, batch, t: r }, s)
        }
        Op::Mul => {
            let k = node.inputs.len();
            let d = node.dim;
            let mut v = values[node.inputs[0]].clone();
            for &p in &node.inputs[1..] {
                v = v.mul(&values[p]);
            }
            let mut g = TangentBatch::zeros(batch, r, d);
            let mut s = Tensor::zeros(&[batch, d]);
            for b in 0..batch {
                let prows: Vec<&[f64]> = node
                    .inputs
                    .iter()
                    .map(|&p| values[p].row(b))
                    .collect();
                for pi in 0..k {
                    let mut coef = vec![1.0; d];
                    for (qi, pr) in prows.iter().enumerate() {
                        if qi != pi {
                            for (c, &xv) in coef.iter_mut().zip(*pr) {
                                *c *= xv;
                            }
                        }
                    }
                    let pg = &tangents[node.inputs[pi]];
                    for kk in 0..r {
                        let src = pg.row(b, kk).to_vec();
                        let dst = g.row_mut(b, kk);
                        for c in 0..d {
                            dst[c] += coef[c] * src[c];
                        }
                    }
                    let psc = &scalars[node.inputs[pi]];
                    {
                        let srow = s.row_mut(b);
                        for c in 0..d {
                            srow[c] += coef[c] * psc.row(b)[c];
                        }
                    }
                    for qi in (pi + 1)..k {
                        let mut coef2 = vec![1.0; d];
                        for (ri, pr) in prows.iter().enumerate() {
                            if ri != pi && ri != qi {
                                for (c, &xv) in coef2.iter_mut().zip(*pr) {
                                    *c *= xv;
                                }
                            }
                        }
                        let gq = &tangents[node.inputs[qi]];
                        let mut cross = vec![0.0; d];
                        for kk in 0..r {
                            let sign = ldl.d[kk];
                            let gp_row = pg.row(b, kk);
                            let gq_row = gq.row(b, kk);
                            for c in 0..d {
                                cross[c] += sign * gp_row[c] * gq_row[c];
                            }
                        }
                        let srow = s.row_mut(b);
                        for c in 0..d {
                            srow[c] += 2.0 * coef2[c] * cross[c];
                        }
                    }
                }
            }
            cost.muls += (batch * d * k * (r + k)) as u64;
            (v, g, s)
        }
        Op::SumReduce => {
            let p = node.inputs[0];
            let mut v = Tensor::zeros(&[batch, 1]);
            let mut s = Tensor::zeros(&[batch, 1]);
            for b in 0..batch {
                v.set(b, 0, values[p].row(b).iter().sum());
                s.set(b, 0, scalars[p].row(b).iter().sum());
            }
            let mut g = TangentBatch::zeros(batch, r, 1);
            for row in 0..batch * r {
                g.data.data_mut()[row] = tangents[p].data.row(row).iter().sum();
            }
            (v, g, s)
        }
        Op::Concat => {
            let mut v = Tensor::zeros(&[batch, node.dim]);
            let mut s = Tensor::zeros(&[batch, node.dim]);
            let mut g = TangentBatch::zeros(batch, r, node.dim);
            for b in 0..batch {
                let mut off = 0;
                for &p in &node.inputs {
                    let pv = values[p].row(b);
                    v.row_mut(b)[off..off + pv.len()].copy_from_slice(pv);
                    let psc = scalars[p].row(b);
                    s.row_mut(b)[off..off + psc.len()].copy_from_slice(psc);
                    off += pv.len();
                }
            }
            for row in 0..batch * r {
                let mut off = 0;
                for &p in &node.inputs {
                    let src = tangents[p].data.row(row);
                    g.data.row_mut(row)[off..off + src.len()].copy_from_slice(src);
                    off += src.len();
                }
            }
            (v, g, s)
        }
    };
    values.push(v);
    tangents.push(g);
    scalars.push(s);
}
