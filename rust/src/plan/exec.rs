//! Executors over a compiled [`OperatorProgram`].
//!
//! * [`execute_dof`] — the benchmark-engine pass (eqs. 7–9) running on one
//!   contiguous slab with statically assigned buffer slots: no arena
//!   lookups, no per-node allocation, no runtime liveness bookkeeping.
//! * [`execute_tape`] — the training-tape pass: same schedule, but every
//!   node tuple is retained as an owned tensor for the reverse sweep
//!   (`dof_backward_tape`), and the tangent width is the full rank `r`
//!   (tape programs are compiled with sparsity off).
//!
//! Both are **storage policies over the shared kernels**
//! ([`super::kernels`]): this module only resolves where each node's
//! `(v, s, g)` tuple lives (slab windows here, owned tensors for the tape)
//! and hands flat slices to the one arithmetic definition the reference
//! interpreter (`DofEngine::compute_with_arena`) also executes — which is
//! why `rust/tests/plan_equivalence.rs` and `rust/tests/cross_engine_fuzz.rs`
//! can assert the paths bit-identical (values, `L[φ]`, FLOP counts, peak
//! tangent bytes).
//!
//! Zeroing discipline: the slab is *not* cleared between calls (slots are
//! reused within and across calls), so every kernel either fully overwrites
//! its destination or explicitly zero-fills accumulation targets first —
//! the same contract the arena's scratch buffers had.

use std::ops::Range;
use std::time::Instant;

use crate::autodiff::dof::DofResult;
use crate::autodiff::dof_tape::DofTape;
use crate::autodiff::forward_jacobian::TangentBatch;
use crate::autodiff::Cost;
use crate::graph::{Graph, Op};
use crate::linalg::LdlDecomposition;
use crate::obs::StepProfiler;
use crate::tensor::{GemmPlan, PackedPanel, Tensor};

use super::kernels;
use super::{NodePlan, OperatorProgram, PanelSet, StepKind};

// ---- slab addressing -----------------------------------------------------

fn v_rng(np: &NodePlan, batch: usize) -> Range<usize> {
    let lo = np.slot * batch;
    lo..lo + batch * np.dim
}

fn s_rng(np: &NodePlan, batch: usize) -> Range<usize> {
    let lo = (np.slot + np.dim) * batch;
    lo..lo + batch * np.dim
}

fn g_rng(np: &NodePlan, batch: usize) -> Range<usize> {
    let lo = (np.slot + 2 * np.dim) * batch;
    lo..lo + batch * np.t() * np.dim
}

fn node_rng(np: &NodePlan, batch: usize) -> Range<usize> {
    let lo = np.slot * batch;
    lo..lo + (np.t() + 2) * np.dim * batch
}

fn scratch_rng(np: &NodePlan, batch: usize) -> Range<usize> {
    let lo = np.scratch * batch;
    lo..lo + np.scratch_len * batch
}

/// Carve one mutable window out of the slab; the remainder is returned as
/// `(absolute offset, slice)` read-only pieces for [`rd`]. Shared with the
/// program-scheduled Hessian executor ([`super::hessian`]).
pub(crate) fn carve1<'a>(
    slab: &'a mut [f64],
    w: &Range<usize>,
) -> (&'a mut [f64], [(usize, &'a [f64]); 2]) {
    let (pre, rest) = slab.split_at_mut(w.start);
    let (win, post) = rest.split_at_mut(w.end - w.start);
    let pre: &'a [f64] = pre;
    let post: &'a [f64] = post;
    (win, [(0, pre), (w.end, post)])
}

/// Carve two disjoint mutable windows (`a`, `b`, in caller order) out of
/// the slab, plus read-only pieces of the remainder.
#[allow(clippy::type_complexity)]
fn carve2<'a>(
    slab: &'a mut [f64],
    a: &Range<usize>,
    b: &Range<usize>,
) -> (&'a mut [f64], &'a mut [f64], [(usize, &'a [f64]); 3]) {
    let swap = b.start < a.start;
    let (lo, hi) = if swap { (b, a) } else { (a, b) };
    debug_assert!(lo.end <= hi.start, "carve2 windows overlap");
    let (p0, rest) = slab.split_at_mut(lo.start);
    let (w_lo, rest) = rest.split_at_mut(lo.end - lo.start);
    let (p1, rest) = rest.split_at_mut(hi.start - lo.end);
    let (w_hi, p2) = rest.split_at_mut(hi.end - hi.start);
    let p0: &'a [f64] = p0;
    let p1: &'a [f64] = p1;
    let p2: &'a [f64] = p2;
    let ros = [(0, p0), (lo.end, p1), (hi.end, p2)];
    if swap {
        (w_hi, w_lo, ros)
    } else {
        (w_lo, w_hi, ros)
    }
}

/// Read a slab range the layout guarantees is disjoint from every write
/// window (addresses are absolute slab offsets).
pub(crate) fn rd<'a>(ros: &[(usize, &'a [f64])], r: Range<usize>) -> &'a [f64] {
    for &(off, s) in ros {
        if r.start >= off && r.end <= off + s.len() {
            return &s[r.start - off..r.end - off];
        }
    }
    panic!("slab read {r:?} overlaps a write window");
}

/// Split a node window into its `(v, s, g)` stream slices.
fn streams(win: &mut [f64], batch: usize, d: usize) -> (&mut [f64], &mut [f64], &mut [f64]) {
    let (v, rest) = win.split_at_mut(batch * d);
    let (s, g) = rest.split_at_mut(batch * d);
    (v, s, g)
}

// ---- the planned DOF pass ------------------------------------------------

/// Execute the compiled program on `x: [batch, N]`, using `slab` as the
/// only tangent storage (grown on first use, reused verbatim afterwards —
/// steady-state executions perform no heap allocation beyond the returned
/// result tensors).
///
/// `panels` is the per-call [`PanelSet`] from [`super::pack_panels`] —
/// packed once per top-level execution by the engine and shared read-only
/// across shards (never cached with the program: panels hold weight
/// values). An all-`None` set is always valid and bit-identical.
pub fn execute_dof(
    program: &OperatorProgram,
    graph: &Graph,
    ldl: &LdlDecomposition,
    b_coef: Option<&[f64]>,
    c_coef: Option<f64>,
    x: &Tensor,
    panels: &PanelSet,
    slab: &mut Vec<f64>,
) -> DofResult {
    execute_dof_profiled(program, graph, ldl, b_coef, c_coef, x, panels, slab, None)
}

/// Stable phase label for a schedule step (shared with the jet executor's
/// profiling hooks).
pub(crate) fn step_label(kind: &StepKind) -> &'static str {
    match kind {
        StepKind::Input { .. } => "input",
        StepKind::Linear {
            fused_act: Some(_), ..
        } => "linear+act",
        StepKind::Linear { .. } => "linear",
        StepKind::Activation => "activation",
        StepKind::Slice => "slice",
        StepKind::Add => "add",
        StepKind::Mul => "mul",
        StepKind::SumReduce => "sum_reduce",
        StepKind::Concat => "concat",
    }
}

/// [`execute_dof`] with optional per-step profiling. With `profiler: None`
/// the hot path pays one branch per step and zero allocation — the two
/// paths run the identical kernel sequence on the identical storage, so
/// profiled execution is bitwise-invisible (asserted by
/// `rust/tests/observability.rs`). Each recorded step carries its measured
/// seconds beside the program's exact analytic step cost.
#[allow(clippy::too_many_arguments)]
pub fn execute_dof_profiled(
    program: &OperatorProgram,
    graph: &Graph,
    ldl: &LdlDecomposition,
    b_coef: Option<&[f64]>,
    c_coef: Option<f64>,
    x: &Tensor,
    panels: &PanelSet,
    slab: &mut Vec<f64>,
    mut profiler: Option<&mut StepProfiler>,
) -> DofResult {
    assert_eq!(x.rank(), 2, "input must be [batch, N]");
    let batch = x.dims()[0];
    assert_eq!(x.dims()[1], program.input_dim(), "input dim mismatch");
    assert_eq!(ldl.rank(), program.rank(), "program/operator rank mismatch");
    assert_eq!(graph.len(), program.node_count(), "program/graph mismatch");
    assert_eq!(
        program.options().lower_order_c,
        c_coef.is_some(),
        "program compiled with different lower-order options"
    );
    let need = program.slab_len(batch);
    if slab.len() < need {
        slab.resize(need, 0.0);
    }
    let slab = &mut slab[..need];

    for (si, step) in program.steps().iter().enumerate() {
        let t0 = profiler.is_some().then(Instant::now);
        match &step.kind {
            StepKind::Input { in_off } => {
                input_step(program, ldl, b_coef, x, batch, slab, step.node, *in_off)
            }
            StepKind::Linear { fused_act, gemm } => {
                let panel = panels.get(step.node).and_then(|p| p.as_ref());
                linear_step(program, graph, batch, slab, step.node, *gemm, panel);
                if let Some(a) = fused_act {
                    activation_step(program, graph, ldl, batch, slab, *a);
                }
            }
            StepKind::Activation => activation_step(program, graph, ldl, batch, slab, step.node),
            StepKind::Slice => slice_step(program, graph, batch, slab, step.node),
            StepKind::Add => add_step(program, graph, batch, slab, step.node),
            StepKind::Mul => mul_step(program, graph, ldl, batch, slab, step.node),
            StepKind::SumReduce => sum_reduce_step(program, graph, batch, slab, step.node),
            StepKind::Concat => concat_step(program, graph, batch, slab, step.node),
        }
        if let (Some(p), Some(t0)) = (profiler.as_deref_mut(), t0) {
            let c = program.step_cost(si, batch);
            p.record(
                step.node,
                step_label(&step.kind),
                t0.elapsed().as_secs_f64(),
                c.muls,
                c.adds,
            );
        }
    }

    let t_fin = profiler.is_some().then(Instant::now);
    // Extract the output tuple into owned tensors.
    let np = program.node_plan(program.output());
    let d = np.dim;
    let t = np.t();
    let values = Tensor::from_vec(&[batch, d], slab[v_rng(np, batch)].to_vec());
    let mut op_vals = Tensor::from_vec(&[batch, d], slab[s_rng(np, batch)].to_vec());
    let out_tangent = TangentBatch {
        data: Tensor::from_vec(&[batch * t, d], slab[g_rng(np, batch)].to_vec()),
        batch,
        t,
    };
    if let Some(c) = c_coef {
        for b in 0..batch {
            for o in 0..d {
                op_vals.set(b, o, op_vals.at(b, o) + c * values.at(b, o));
            }
        }
    }
    if let (Some(p), Some(t0)) = (profiler.as_deref_mut(), t_fin) {
        let c = program.finalize_cost(batch);
        p.record(
            usize::MAX,
            "finalize",
            t0.elapsed().as_secs_f64(),
            c.muls,
            c.adds,
        );
    }
    DofResult {
        values,
        out_tangent,
        out_active: np.active.clone(),
        operator_values: op_vals,
        cost: program.cost(batch),
        peak_tangent_bytes: program.peak_tangent_bytes(batch),
    }
}

#[allow(clippy::too_many_arguments)]
fn input_step(
    program: &OperatorProgram,
    ldl: &LdlDecomposition,
    b_coef: Option<&[f64]>,
    x: &Tensor,
    batch: usize,
    slab: &mut [f64],
    id: usize,
    in_off: usize,
) {
    let np = program.node_plan(id);
    let w = node_rng(np, batch);
    let (win, _ros) = carve1(slab, &w);
    let (v, s, g) = streams(win, batch, np.dim);
    kernels::input_seed(
        x, in_off, np.dim, batch, b_coef, &ldl.l, &np.active, v, s, g,
    );
}

#[allow(clippy::too_many_arguments)]
fn linear_step(
    program: &OperatorProgram,
    graph: &Graph,
    batch: usize,
    slab: &mut [f64],
    id: usize,
    gemm: GemmPlan,
    panel: Option<&PackedPanel>,
) {
    let node = graph.node(id);
    let (weight, bias) = match &node.op {
        Op::Linear { weight, bias } => (weight, bias),
        _ => unreachable!("linear step on non-linear node"),
    };
    let p = node.inputs[0];
    let np = program.node_plan(id);
    let pp = program.node_plan(p);
    let in_d = weight.dims()[1];
    let t = pp.t();
    debug_assert_eq!(np.t(), t);
    let rows = batch * (t + 2);
    let sc = scratch_rng(np, batch);
    let w = node_rng(np, batch);
    let (sc_win, w_win, ros) = carve2(slab, &sc, &w);
    let (stacked, gout) = sc_win.split_at_mut(rows * in_d);
    let (v, s, g) = streams(w_win, batch, np.dim);
    let pv = rd(&ros, v_rng(pp, batch));
    let ps = rd(&ros, s_rng(pp, batch));
    let pg = rd(&ros, g_rng(pp, batch));
    kernels::linear_forward(
        weight, bias, gemm, panel, batch, t, pv, ps, pg, stacked, gout, v, s, g,
    );
}

fn activation_step(
    program: &OperatorProgram,
    graph: &Graph,
    ldl: &LdlDecomposition,
    batch: usize,
    slab: &mut [f64],
    id: usize,
) {
    let node = graph.node(id);
    let act = match &node.op {
        Op::Activation { act } => *act,
        _ => unreachable!("activation step on non-activation node"),
    };
    let p = node.inputs[0];
    let np = program.node_plan(id);
    let pp = program.node_plan(p);
    let w = node_rng(np, batch);
    let (win, ros) = carve1(slab, &w);
    let h = rd(&ros, v_rng(pp, batch));
    let ps = rd(&ros, s_rng(pp, batch));
    let pg = rd(&ros, g_rng(pp, batch));
    let (v, s, g) = streams(win, batch, np.dim);
    kernels::activation_forward(act, &ldl.d, &np.active, batch, np.dim, h, ps, pg, v, s, g);
}

fn slice_step(program: &OperatorProgram, graph: &Graph, batch: usize, slab: &mut [f64], id: usize) {
    let node = graph.node(id);
    let (start, len) = match &node.op {
        Op::Slice { start, len } => (*start, *len),
        _ => unreachable!("slice step on non-slice node"),
    };
    let p = node.inputs[0];
    let np = program.node_plan(id);
    let pp = program.node_plan(p);
    let pd = pp.dim;
    let tp = pp.t();
    let t = np.t();
    let w = node_rng(np, batch);
    let (win, ros) = carve1(slab, &w);
    let pv = rd(&ros, v_rng(pp, batch));
    let psl = rd(&ros, s_rng(pp, batch));
    let pg = rd(&ros, g_rng(pp, batch));
    let (v, s, g) = streams(win, batch, len);
    for b in 0..batch {
        v[b * len..(b + 1) * len].copy_from_slice(&pv[b * pd + start..b * pd + start + len]);
        s[b * len..(b + 1) * len].copy_from_slice(&psl[b * pd + start..b * pd + start + len]);
    }
    // Only the rows the compile-time compaction kept are copied; rows that
    // are structurally zero inside the slice window were pruned at compile.
    for b in 0..batch {
        for (nk, &kk) in np.keep.iter().enumerate() {
            let src = &pg[(b * tp + kk) * pd + start..(b * tp + kk) * pd + start + len];
            let o = (b * t + nk) * len;
            g[o..o + len].copy_from_slice(src);
        }
    }
}

fn add_step(program: &OperatorProgram, graph: &Graph, batch: usize, slab: &mut [f64], id: usize) {
    let node = graph.node(id);
    let np = program.node_plan(id);
    let d = np.dim;
    let t = np.t();
    let w = node_rng(np, batch);
    let (win, ros) = carve1(slab, &w);
    let (v, s, g) = streams(win, batch, d);
    for (pi, &p) in node.inputs.iter().enumerate() {
        let pp = program.node_plan(p);
        let pv = rd(&ros, v_rng(pp, batch));
        let psl = rd(&ros, s_rng(pp, batch));
        if pi == 0 {
            v.copy_from_slice(pv);
            s.copy_from_slice(psl);
        } else {
            for (dst, &sv) in v.iter_mut().zip(pv.iter()) {
                *dst += sv;
            }
            for (dst, &sv) in s.iter_mut().zip(psl.iter()) {
                *dst += sv;
            }
        }
    }
    // Union-aligned tangent sum: zero, then accumulate each parent's rows
    // at their precomputed union positions.
    g.fill(0.0);
    for (pi, &p) in node.inputs.iter().enumerate() {
        let pp = program.node_plan(p);
        let tp = pp.t();
        let pg = rd(&ros, g_rng(pp, batch));
        let pos = &np.parent_pos[pi];
        for b in 0..batch {
            for (kk, &u) in pos.iter().enumerate() {
                let src = &pg[(b * tp + kk) * d..(b * tp + kk + 1) * d];
                let dst = &mut g[(b * t + u) * d..(b * t + u + 1) * d];
                for c in 0..d {
                    dst[c] += src[c];
                }
            }
        }
    }
}

fn concat_step(program: &OperatorProgram, graph: &Graph, batch: usize, slab: &mut [f64], id: usize) {
    let node = graph.node(id);
    let np = program.node_plan(id);
    let d = np.dim;
    let t = np.t();
    let w = node_rng(np, batch);
    let (win, ros) = carve1(slab, &w);
    let (v, s, g) = streams(win, batch, d);
    let mut off = 0usize;
    for &p in &node.inputs {
        let pp = program.node_plan(p);
        let pd = pp.dim;
        let pv = rd(&ros, v_rng(pp, batch));
        let psl = rd(&ros, s_rng(pp, batch));
        for b in 0..batch {
            v[b * d + off..b * d + off + pd].copy_from_slice(&pv[b * pd..(b + 1) * pd]);
            s[b * d + off..b * d + off + pd].copy_from_slice(&psl[b * pd..(b + 1) * pd]);
        }
        off += pd;
    }
    g.fill(0.0);
    let mut off = 0usize;
    for (pi, &p) in node.inputs.iter().enumerate() {
        let pp = program.node_plan(p);
        let pd = pp.dim;
        let tp = pp.t();
        let pg = rd(&ros, g_rng(pp, batch));
        let pos = &np.parent_pos[pi];
        for b in 0..batch {
            for (kk, &u) in pos.iter().enumerate() {
                let src = &pg[(b * tp + kk) * pd..(b * tp + kk + 1) * pd];
                let o = (b * t + u) * d + off;
                g[o..o + pd].copy_from_slice(src);
            }
        }
        off += pd;
    }
}

fn mul_step(
    program: &OperatorProgram,
    graph: &Graph,
    ldl: &LdlDecomposition,
    batch: usize,
    slab: &mut [f64],
    id: usize,
) {
    let node = graph.node(id);
    let np = program.node_plan(id);
    let d = np.dim;
    let t = np.t();
    let k = node.inputs.len();

    // Phase 1: materialize every parent's union-aligned tangent in the step
    // scratch (zero-filled missing rows) — the `expand_to` of the
    // interpreter, but into preassigned storage. Alignment is storage
    // policy; the product rule itself is the shared kernel below.
    let sc = scratch_rng(np, batch);
    {
        let (win, ros) = carve1(slab, &sc);
        win.fill(0.0);
        for (pi, &p) in node.inputs.iter().enumerate() {
            let pp = program.node_plan(p);
            let tp = pp.t();
            let pg = rd(&ros, g_rng(pp, batch));
            let pos = &np.parent_pos[pi];
            let block = pi * batch * t * d;
            for b in 0..batch {
                for (kk, &u) in pos.iter().enumerate() {
                    let src = &pg[(b * tp + kk) * d..(b * tp + kk + 1) * d];
                    let o = block + (b * t + u) * d;
                    win[o..o + d].copy_from_slice(src);
                }
            }
        }
    }

    // Phase 2: the eq. 9 product rule (shared kernel) over the aligned
    // tangents.
    let w = node_rng(np, batch);
    let (win, ros) = carve1(slab, &w);
    let (v, s, g) = streams(win, batch, d);
    let pvals: Vec<&[f64]> = node
        .inputs
        .iter()
        .map(|&p| rd(&ros, v_rng(program.node_plan(p), batch)))
        .collect();
    let psums: Vec<&[f64]> = node
        .inputs
        .iter()
        .map(|&p| rd(&ros, s_rng(program.node_plan(p), batch)))
        .collect();
    let aligned_all = rd(&ros, sc.clone());
    let aligned: Vec<&[f64]> = if batch * t * d == 0 {
        vec![&[][..]; k]
    } else {
        aligned_all.chunks_exact(batch * t * d).collect()
    };
    kernels::mul_forward(&ldl.d, &np.active, batch, d, &pvals, &psums, &aligned, v, s, g);
}

fn sum_reduce_step(
    program: &OperatorProgram,
    graph: &Graph,
    batch: usize,
    slab: &mut [f64],
    id: usize,
) {
    let node = graph.node(id);
    let p = node.inputs[0];
    let np = program.node_plan(id);
    let pp = program.node_plan(p);
    let pd = pp.dim;
    let t = np.t();
    let w = node_rng(np, batch);
    let (win, ros) = carve1(slab, &w);
    let pv = rd(&ros, v_rng(pp, batch));
    let psl = rd(&ros, s_rng(pp, batch));
    let pg = rd(&ros, g_rng(pp, batch));
    let (v, s, g) = streams(win, batch, 1);
    for b in 0..batch {
        v[b] = pv[b * pd..(b + 1) * pd].iter().sum::<f64>();
        s[b] = psl[b * pd..(b + 1) * pd].iter().sum::<f64>();
    }
    for row in 0..batch * t {
        g[row] = pg[row * pd..(row + 1) * pd].iter().sum::<f64>();
    }
}

// ---- the planned training tape -------------------------------------------

/// Forward DOF pass over the program schedule that retains every node
/// tuple as owned tensors — the input to [`crate::autodiff::dof_tape`]'s
/// reverse sweep. Requires a program compiled with `sparsity: false` (the
/// tape always carries the full rank-`r` tangent, like the pre-plan
/// implementation). Runs the same shared kernels as the slab executor and
/// the interpreter, with owned tensors as the storage policy.
pub fn execute_tape(
    program: &OperatorProgram,
    graph: &Graph,
    ldl: &LdlDecomposition,
    b_coef: Option<&[f64]>,
    x: &Tensor,
) -> DofTape {
    assert!(
        !program.options().sparsity,
        "tape programs are compiled dense (full tangent width)"
    );
    assert_eq!(graph.len(), program.node_count(), "program/graph mismatch");
    let n = graph.input_dim();
    assert_eq!(ldl.n, n);
    let batch = x.dims()[0];
    let r = ldl.rank();
    let full: Vec<usize> = (0..r).collect();
    let mut cost = Cost::zero();
    let mut values: Vec<Tensor> = Vec::with_capacity(graph.len());
    let mut tangents: Vec<TangentBatch> = Vec::with_capacity(graph.len());
    let mut scalars: Vec<Tensor> = Vec::with_capacity(graph.len());

    for step in program.steps() {
        tape_node(
            graph,
            ldl,
            b_coef,
            x,
            batch,
            r,
            &full,
            step.node,
            &step.kind,
            &mut values,
            &mut tangents,
            &mut scalars,
            &mut cost,
        );
        if let StepKind::Linear {
            fused_act: Some(a), ..
        } = &step.kind
        {
            tape_node(
                graph,
                ldl,
                b_coef,
                x,
                batch,
                r,
                &full,
                *a,
                &StepKind::Activation,
                &mut values,
                &mut tangents,
                &mut scalars,
                &mut cost,
            );
        }
    }

    DofTape {
        values,
        tangents,
        scalars,
        batch,
        r,
        cost,
    }
}

/// One node of the retained-tape pass: the shared kernels with owned-tensor
/// storage and the engines' **exact** FLOP convention (every mul and add of
/// the eq. 7–9 pass, term for term the reference interpreter's charges with
/// `t = r`) — so a dense program's analytic [`OperatorProgram::cost`]
/// equals the tape's measured `cost` exactly, asserted by
/// `rust/tests/cross_engine_fuzz.rs`.
#[allow(clippy::too_many_arguments)]
fn tape_node(
    graph: &Graph,
    ldl: &LdlDecomposition,
    b_coef: Option<&[f64]>,
    x: &Tensor,
    batch: usize,
    r: usize,
    full: &[usize],
    id: usize,
    kind: &StepKind,
    values: &mut Vec<Tensor>,
    tangents: &mut Vec<TangentBatch>,
    scalars: &mut Vec<Tensor>,
    cost: &mut Cost,
) {
    debug_assert_eq!(values.len(), id, "tape must fill nodes in graph order");
    let node = graph.node(id);
    let (v, g, s) = match &node.op {
        Op::Input { dim } => {
            let in_off = match kind {
                StepKind::Input { in_off } => *in_off,
                _ => unreachable!("input node scheduled as non-input step"),
            };
            let mut v = Tensor::zeros(&[batch, *dim]);
            let mut g = TangentBatch::zeros(batch, r, *dim);
            let mut s = Tensor::zeros(&[batch, *dim]);
            kernels::input_seed(
                x,
                in_off,
                *dim,
                batch,
                b_coef,
                &ldl.l,
                full,
                v.data_mut(),
                s.data_mut(),
                g.data.data_mut(),
            );
            (v, g, s)
        }
        Op::Linear { weight, bias } => {
            let p = node.inputs[0];
            let gemm = match kind {
                StepKind::Linear { gemm, .. } => *gemm,
                _ => unreachable!("linear node scheduled as non-linear step"),
            };
            let (out_d, in_d) = (weight.dims()[0], weight.dims()[1]);
            let rows = batch * (r + 2);
            let mut stacked = Tensor::zeros(&[rows, in_d]);
            let mut gout = Tensor::zeros(&[rows, out_d]);
            let mut v = Tensor::zeros(&[batch, out_d]);
            let mut s = Tensor::zeros(&[batch, out_d]);
            let mut g = TangentBatch::zeros(batch, r, out_d);
            kernels::linear_forward(
                weight,
                bias,
                gemm,
                None,
                batch,
                r,
                values[p].data(),
                scalars[p].data(),
                tangents[p].data.data(),
                stacked.data_mut(),
                gout.data_mut(),
                v.data_mut(),
                s.data_mut(),
                g.data.data_mut(),
            );
            cost.muls += ((batch * (r + 2)) * out_d * in_d) as u64;
            cost.adds += (batch * r * out_d * in_d) as u64;
            (v, g, s)
        }
        Op::Activation { act } => {
            let p = node.inputs[0];
            let d = node.dim;
            let mut v = Tensor::zeros(&[batch, d]);
            let mut s = Tensor::zeros(&[batch, d]);
            let mut g = TangentBatch::zeros(batch, r, d);
            kernels::activation_forward(
                *act,
                &ldl.d,
                full,
                batch,
                d,
                values[p].data(),
                scalars[p].data(),
                tangents[p].data.data(),
                v.data_mut(),
                s.data_mut(),
                g.data.data_mut(),
            );
            cost.muls += (batch * (2 * r * d + 2 * d)) as u64;
            cost.adds += (batch * (r * d + d)) as u64;
            (v, g, s)
        }
        Op::Slice { start, len } => {
            let p = node.inputs[0];
            let mut v = Tensor::zeros(&[batch, *len]);
            let mut s = Tensor::zeros(&[batch, *len]);
            for b in 0..batch {
                v.row_mut(b)
                    .copy_from_slice(&values[p].row(b)[*start..*start + *len]);
                s.row_mut(b)
                    .copy_from_slice(&scalars[p].row(b)[*start..*start + *len]);
            }
            let mut g = TangentBatch::zeros(batch, r, *len);
            for row in 0..batch * r {
                g.data
                    .row_mut(row)
                    .copy_from_slice(&tangents[p].data.row(row)[*start..*start + *len]);
            }
            (v, g, s)
        }
        Op::Add => {
            let p0 = node.inputs[0];
            let d = node.dim;
            let mut v = values[p0].clone();
            let mut gd = tangents[p0].data.clone();
            let mut s = scalars[p0].clone();
            for &p in &node.inputs[1..] {
                v = v.add(&values[p]);
                gd = gd.add(&tangents[p].data);
                s = s.add(&scalars[p]);
                cost.adds += (batch * (r * d + 2 * d)) as u64;
            }
            (v, TangentBatch { data: gd, batch, t: r }, s)
        }
        Op::Mul => {
            let k = node.inputs.len();
            let d = node.dim;
            let pvals: Vec<&[f64]> = node.inputs.iter().map(|&p| values[p].data()).collect();
            let psums: Vec<&[f64]> = node.inputs.iter().map(|&p| scalars[p].data()).collect();
            let aligned: Vec<&[f64]> = node
                .inputs
                .iter()
                .map(|&p| tangents[p].data.data())
                .collect();
            let mut v = Tensor::zeros(&[batch, d]);
            let mut s = Tensor::zeros(&[batch, d]);
            let mut g = TangentBatch::zeros(batch, r, d);
            kernels::mul_forward(
                &ldl.d,
                full,
                batch,
                d,
                &pvals,
                &psums,
                &aligned,
                v.data_mut(),
                s.data_mut(),
                g.data.data_mut(),
            );
            cost.muls += ((k - 1) * batch * d) as u64;
            cost.muls += (batch * k * ((k - 1) * d + r * d + d)) as u64;
            cost.muls += (batch * (k * (k - 1) / 2) * (r * d + 2 * d)) as u64;
            (v, g, s)
        }
        Op::SumReduce => {
            let p = node.inputs[0];
            let pd = graph.node(p).dim;
            let mut v = Tensor::zeros(&[batch, 1]);
            let mut s = Tensor::zeros(&[batch, 1]);
            for b in 0..batch {
                v.set(b, 0, values[p].row(b).iter().sum());
                s.set(b, 0, scalars[p].row(b).iter().sum());
            }
            let mut g = TangentBatch::zeros(batch, r, 1);
            for row in 0..batch * r {
                g.data.data_mut()[row] = tangents[p].data.row(row).iter().sum();
            }
            cost.adds += (batch * (r * pd + 2 * pd)) as u64;
            (v, g, s)
        }
        Op::Concat => {
            let mut v = Tensor::zeros(&[batch, node.dim]);
            let mut s = Tensor::zeros(&[batch, node.dim]);
            let mut g = TangentBatch::zeros(batch, r, node.dim);
            for b in 0..batch {
                let mut off = 0;
                for &p in &node.inputs {
                    let pv = values[p].row(b);
                    v.row_mut(b)[off..off + pv.len()].copy_from_slice(pv);
                    let psc = scalars[p].row(b);
                    s.row_mut(b)[off..off + psc.len()].copy_from_slice(psc);
                    off += pv.len();
                }
            }
            for row in 0..batch * r {
                let mut off = 0;
                for &p in &node.inputs {
                    let src = tangents[p].data.row(row);
                    g.data.row_mut(row)[off..off + src.len()].copy_from_slice(src);
                    off += src.len();
                }
            }
            (v, g, s)
        }
    };
    values.push(v);
    tangents.push(g);
    scalars.push(s);
}
