//! Program-scheduled execution of the **Hessian baseline** (Appendix B,
//! eqs. 12–14) — the compile-once twin of [`super::exec::execute_dof`],
//! closing the ROADMAP PR-2 follow-up: the baseline the paper's Table 1
//! compares against now runs on the same compiled machinery as the DOF
//! engine, so its FLOP/peak numbers come from the same analytic replay the
//! slab executors use.
//!
//! A [`HessianPlan`] is compiled once per graph *structure* (the Hessian
//! method is operator-value-independent: `A`, `b`, `c` only enter the final
//! contraction) and carries:
//!
//! * the shared **schedule** ([`super::build_schedule`], fused
//!   `Linear → Activation` steps) driving the forward value/Jacobian sweep;
//! * a **static slab layout**: every node's width-`N` forward tangent
//!   `∇vⁱ` and reverse second-order adjoint `∇v̄ⁱ` at a fixed per-row
//!   offset, assigned by replaying the reference path's exact alloc/free
//!   event order (forward tangents live until their own reverse step —
//!   that is Appendix D's memory story — `∇v̄ⁱ` from its first contributing
//!   consumer to its own step), plus one contribution scratch block;
//! * **exact analytic costs** — per-row FLOPs mirroring every charge of
//!   the reference path (forward Jacobian, eq. 12 adjoints, eq. 14 sweep,
//!   contraction) and the peak-byte replay of its [`PeakTracker`] events,
//!   both exactly linear in the batch;
//! * the cached `I_N` Jacobian seed.
//!
//! [`execute_hessian`] then runs values (graph order), the forward
//! Jacobian (schedule order, slab slots, shared [`super::kernels`]), the
//! eq. 12 adjoint sweep ([`crate::autodiff::backward`] — tiny `[batch, d]`
//! buffers, no tangents), and the eq. 14 reverse sweep (reverse schedule
//! order, slab slots, shared kernels). The arithmetic is the reference
//! path's ([`crate::autodiff::HessianEngine::compute_reference`]) through
//! the same kernels, so the two are bit-identical — asserted by
//! `rust/tests/cross_engine_fuzz.rs` and the determinism suite, including
//! FLOP counts and peak bytes (analytic here ≡ measured there).
//!
//! Plans are **shard-invariant** (structure only — never batch size or
//! thread count), so `compute_sharded` compiles once and every shard
//! executes the same plan under the PR 1 determinism contract.

use std::ops::Range;
use std::sync::{Arc, OnceLock};
use std::time::Instant;

use crate::autodiff::backward::backward;
use crate::autodiff::hessian::HessianResult;
use crate::autodiff::Cost;
use crate::graph::{Graph, Op};
use crate::obs::StepProfiler;
use crate::tensor::{matmul_nt_planned, GemmPlan, Tensor};
use crate::util::keyed_cache::KeyedCache;

use super::exec::{carve1, rd, step_label};
use super::kernels;
use super::layout::SlabLayout;
use super::{build_schedule, hash_graph_structure, Fnv, PanelSet, Step, StepKind};

/// Cache key: graph structure + `N`, domain-tagged so Hessian slabs never
/// collide with DOF program slabs of the same graph in the program-keyed
/// slab pool.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct HessianKey {
    pub fingerprint: u64,
    pub nodes: usize,
    pub n: usize,
}

/// Value-independent structural fingerprint of a graph, in the Hessian
/// plan's key domain.
pub fn hessian_key(graph: &Graph) -> HessianKey {
    let mut h = Fnv::new();
    h.u64(0x4845_5353); // "HESS" domain tag
    hash_graph_structure(&mut h, graph);
    h.u64(graph.input_dim() as u64);
    HessianKey {
        fingerprint: h.0,
        nodes: graph.len(),
        n: graph.input_dim(),
    }
}

/// A compiled, reusable Hessian-method execution plan for one graph
/// structure (see module docs).
pub struct HessianPlan {
    steps: Vec<Step>,
    /// Per-row slab offset of each node's forward tangent (`n·dim` units).
    fwd_slot: Vec<usize>,
    /// Per-row slab offset of each node's `∇v̄` block (`n·dim` units);
    /// `usize::MAX` for nodes that never receive one (unconsumed inputs).
    gbar_slot: Vec<usize>,
    /// Per-row offset/length of the contribution scratch (`n·max_dim`).
    scratch_slot: usize,
    scratch_len: usize,
    out_id: usize,
    n: usize,
    slab_per_row: usize,
    cost_per_row: Cost,
    /// Per-row cost of each forward-Jacobian step (fused activation folded
    /// into its Linear step, mirroring the schedule).
    fwd_step_costs: Vec<Cost>,
    /// Per-row cost of the eq. 12 adjoint sweep (one `backward` pass).
    adjoint_cost_per_row: Cost,
    /// Per-row cost of each node's eq. 14 reverse-sweep visit (indexed by
    /// node id; zero for inputs and flop-free reshapes).
    rev_node_costs: Vec<Cost>,
    /// Per-row cost of the final `Σ aᵢⱼ Hᵢⱼ` contraction (lower-order `b`/`c`
    /// extras are engine configuration, charged at execution).
    contract_cost_per_row: Cost,
    peak_per_row: u64,
    key: HessianKey,
    identity_seed: OnceLock<Tensor>,
}

impl HessianPlan {
    /// Compile a plan. Cost is O(nodes); no batch-data arithmetic.
    pub fn compile(graph: &Graph) -> Self {
        let n = graph.input_dim();
        let len = graph.len();
        assert!(len > 0, "cannot compile an empty graph");
        let out_id = graph.output();
        let tau = graph.tau();
        let mut steps = build_schedule(graph, &tau);
        // Plan-time micro-kernel selection: the Jacobian sweep pushes `n`
        // width-`N` tangent rows per batch row through each Linear, so the
        // batch-invariant per-item row count is `n` itself.
        for step in steps.iter_mut() {
            if let StepKind::Linear { gemm, .. } = &mut step.kind {
                if let Op::Linear { weight, .. } = &graph.node(step.node).op {
                    let (out_d, in_d) = (weight.dims()[0], weight.dims()[1]);
                    *gemm = GemmPlan::choose(n, in_d, out_d);
                }
            }
        }
        let dim = |j: usize| graph.node(j).dim;
        let is_input = |j: usize| matches!(graph.node(j).op, Op::Input { .. });

        // ---- static slab layout: replay the reference lifetimes ---------
        let mut lay = SlabLayout::new();
        let mut fwd_slot = vec![0usize; len];
        for (j, slot) in fwd_slot.iter_mut().enumerate() {
            *slot = lay.alloc(n * dim(j));
        }
        let max_dim = graph.nodes().iter().map(|nd| nd.dim).max().unwrap_or(0);
        let scratch_len = n * max_dim;
        let scratch_slot = lay.alloc(scratch_len);
        let mut gbar_slot = vec![usize::MAX; len];
        let mut has = vec![false; len];
        gbar_slot[out_id] = lay.alloc(n * dim(out_id));
        has[out_id] = true;
        for j in (0..len).rev() {
            if is_input(j) {
                continue;
            }
            if !has[j] {
                // Never-contributed node: the executor zero-fills a block
                // of its own (mirroring the reference's untracked zeros).
                gbar_slot[j] = lay.alloc(n * dim(j));
                has[j] = true;
            }
            for &p in &graph.node(j).inputs {
                if !has[p] {
                    gbar_slot[p] = lay.alloc(n * dim(p));
                    has[p] = true;
                }
            }
            lay.free(gbar_slot[j], n * dim(j));
            lay.free(fwd_slot[j], n * dim(j));
        }
        let slab_per_row = lay.high_water();

        // ---- exact peak replay (the reference PeakTracker's events) -----
        let mut cur = 0u64;
        let mut peak = 0u64;
        fn bump(cur: &mut u64, peak: &mut u64, x: u64) {
            *cur += x;
            if *cur > *peak {
                *peak = *cur;
            }
        }
        for j in 0..len {
            bump(&mut cur, &mut peak, (n * dim(j)) as u64);
        }
        bump(&mut cur, &mut peak, (n * dim(out_id)) as u64);
        let mut tracked = vec![false; len];
        tracked[out_id] = true;
        for j in (0..len).rev() {
            if is_input(j) {
                continue;
            }
            for &p in &graph.node(j).inputs {
                if !tracked[p] {
                    bump(&mut cur, &mut peak, (n * dim(p)) as u64);
                    tracked[p] = true;
                }
            }
            // ∇v̄^j consumed; its forward tangent dies with it. (A node
            // that never received a contribution frees untracked zeros —
            // the reference's tracker saturates identically.)
            cur = cur.saturating_sub((n * dim(j)) as u64);
            cur = cur.saturating_sub((n * dim(j)) as u64);
        }

        // ---- exact per-row cost (mirrors the reference charge by charge),
        // stored per phase/step so the profiler's analytic column sums to
        // the plan total by construction.
        let phases = phase_costs(graph, n);
        let fwd_step_costs: Vec<Cost> = steps
            .iter()
            .map(|step| {
                let mut c = phases.fwd[step.node];
                if let StepKind::Linear {
                    fused_act: Some(ai),
                    ..
                } = &step.kind
                {
                    let ac = phases.fwd[*ai];
                    c.muls += ac.muls;
                    c.adds += ac.adds;
                }
                c
            })
            .collect();
        let contract_cost_per_row = Cost {
            muls: (n * n) as u64,
            adds: (n * n) as u64,
        };
        let mut cost_per_row = contract_cost_per_row;
        cost_per_row.muls += phases.adjoint.muls;
        cost_per_row.adds += phases.adjoint.adds;
        for c in fwd_step_costs.iter().chain(phases.rev.iter()) {
            cost_per_row.muls += c.muls;
            cost_per_row.adds += c.adds;
        }

        HessianPlan {
            steps,
            fwd_slot,
            gbar_slot,
            scratch_slot,
            scratch_len,
            out_id,
            n,
            slab_per_row,
            cost_per_row,
            fwd_step_costs,
            adjoint_cost_per_row: phases.adjoint,
            rev_node_costs: phases.rev,
            contract_cost_per_row,
            peak_per_row: peak,
            key: hessian_key(graph),
            identity_seed: OnceLock::new(),
        }
    }

    pub fn key(&self) -> HessianKey {
        self.key
    }

    /// The compiled schedule — exposed so callers can pack weight panels
    /// ([`super::pack_panels`]) once per top-level execution.
    pub fn steps(&self) -> &[Step] {
        &self.steps
    }

    pub fn input_dim(&self) -> usize {
        self.n
    }

    pub fn node_count(&self) -> usize {
        self.fwd_slot.len()
    }

    /// Per-row slab scalars; one shard's slab is `slab_per_row · rows`.
    pub fn slab_per_row(&self) -> usize {
        self.slab_per_row
    }

    /// Slab length (f64 scalars) for a `batch`-row execution.
    pub fn slab_len(&self, batch: usize) -> usize {
        self.slab_per_row * batch
    }

    /// Exact FLOP count of a `batch`-row execution — identical to the
    /// reference path's runtime accumulation. The lower-order terms are
    /// engine configuration, not plan structure, so they are parameters.
    pub fn cost(&self, batch: usize, has_b: bool, has_c: bool) -> Cost {
        let mut c = Cost {
            muls: self.cost_per_row.muls * batch as u64,
            adds: self.cost_per_row.adds * batch as u64,
        };
        if has_b {
            c.muls += (batch * self.n) as u64;
        }
        if has_c {
            c.muls += batch as u64;
        }
        c
    }

    /// Exact peak live tangent bytes of a `batch`-row execution — the
    /// Theorem 2.2 `M₂` measurement, replayed from the reference path's
    /// alloc/free event order.
    pub fn peak_tangent_bytes(&self, batch: usize) -> u64 {
        self.peak_per_row * 8 * batch as u64
    }

    /// The cached `I_N` Jacobian seed (eq. 13), built on first use.
    pub fn identity_seed(&self) -> &Tensor {
        self.identity_seed.get_or_init(|| Tensor::eye(self.n))
    }
}

/// Per-row charges of the reference path, split by execution phase. The sum
/// of every entry plus the contraction reproduces the reference's runtime
/// accumulation charge by charge (the old single-total formula, exploded so
/// the profiler can attribute each step exactly).
struct PhaseCosts {
    /// Forward Jacobian (eq. 13) cost per node.
    fwd: Vec<Cost>,
    /// The whole eq. 12 adjoint sweep (one tiny `backward` pass).
    adjoint: Cost,
    /// Eq. 14 reverse-sweep cost per node.
    rev: Vec<Cost>,
}

fn phase_costs(graph: &Graph, n: usize) -> PhaseCosts {
    let mut fwd = vec![Cost::zero(); graph.len()];
    let mut adjoint = Cost::zero();
    let mut rev = vec![Cost::zero(); graph.len()];
    for (j, node) in graph.nodes().iter().enumerate() {
        let d = node.dim;
        match &node.op {
            Op::Input { .. } | Op::Slice { .. } | Op::Concat => {}
            Op::Linear { weight, .. } => {
                let (o, i) = (weight.dims()[0], weight.dims()[1]);
                // forward n·o·i (+adds), backward o·i (+adds),
                // sweep n·o·i (+adds).
                fwd[j].muls += (n * o * i) as u64;
                fwd[j].adds += (n * o * i) as u64;
                adjoint.muls += (o * i) as u64;
                adjoint.adds += (o * i) as u64;
                rev[j].muls += (n * o * i) as u64;
                rev[j].adds += (n * o * i) as u64;
            }
            Op::Activation { .. } => {
                // forward n·d; backward d; sweep d + 2·n·d (+ n·d adds).
                fwd[j].muls += (n * d) as u64;
                adjoint.muls += d as u64;
                rev[j].muls += (d + 2 * n * d) as u64;
                rev[j].adds += (n * d) as u64;
            }
            Op::Add => {
                let k = node.inputs.len();
                // forward (k−1)·n·d adds; backward k·d adds.
                fwd[j].adds += ((k - 1) * n * d) as u64;
                adjoint.adds += (k * d) as u64;
            }
            Op::Mul => {
                let k = node.inputs.len();
                // forward: per parent (k−1)·d + n·d muls, n·d adds.
                fwd[j].muls += (k * ((k - 1) * d + n * d)) as u64;
                fwd[j].adds += (k * n * d) as u64;
                // backward: per parent (k−1)·d muls.
                adjoint.muls += (k * (k - 1) * d) as u64;
                // sweep: per parent n·d + (k−1)·(d + n·d) muls,
                // (k−1)·n·d adds.
                rev[j].muls += (k * (n * d + (k - 1) * (d + n * d))) as u64;
                rev[j].adds += (k * (k - 1) * n * d) as u64;
            }
            Op::SumReduce => {
                let pd = graph.node(node.inputs[0]).dim;
                fwd[j].adds += (n * pd) as u64;
            }
        }
    }
    PhaseCosts { fwd, adjoint, rev }
}

// ---- plan cache ----------------------------------------------------------

/// Bound on retained plans (oldest evicted past this).
pub const HESSIAN_CACHE_CAP: usize = 32;

/// Hit/miss counters plus current occupancy (the shared
/// [`crate::util::CacheStats`] shape).
pub type HessianCacheStats = crate::util::CacheStats;

/// A keyed Hessian-plan cache — the Hessian consumer of the shared
/// double-checked [`KeyedCache`] ([`crate::util::keyed_cache`]); this
/// wrapper only contributes the key derivation and the compile closure.
pub struct HessianPlanCache {
    inner: KeyedCache<HessianKey, HessianPlan>,
}

impl HessianPlanCache {
    pub const fn new() -> Self {
        Self {
            inner: KeyedCache::new(HESSIAN_CACHE_CAP),
        }
    }

    /// Fetch the plan for `graph`, compiling on first use.
    pub fn get_or_compile(&self, graph: &Graph) -> Arc<HessianPlan> {
        let key = hessian_key(graph);
        self.inner
            .get_or_insert_with(key, || HessianPlan::compile(graph))
    }

    pub fn stats(&self) -> HessianCacheStats {
        self.inner.stats()
    }

    /// Drop every retained plan (counters are kept).
    pub fn clear(&self) {
        self.inner.clear()
    }
}

impl Default for HessianPlanCache {
    fn default() -> Self {
        Self::new()
    }
}

static GLOBAL: HessianPlanCache = HessianPlanCache::new();

/// The process-wide Hessian-plan cache used by the engine's `compute*`
/// wrappers and shared with program-held plans.
pub fn global_hessian_cache() -> &'static HessianPlanCache {
    &GLOBAL
}

// ---- the planned Hessian pass --------------------------------------------

fn block(slot: usize, units: usize, batch: usize) -> Range<usize> {
    let lo = slot * batch;
    lo..lo + units * batch
}

/// Execute the compiled plan on `x: [batch, N]` with `slab` as the only
/// tangent storage. Arithmetic is the reference path's, through the shared
/// kernels, so results — values, gradient, Hessian, `L[φ]` — are
/// bit-identical to [`crate::autodiff::HessianEngine::compute_reference`];
/// `cost` and `peak_tangent_bytes` are the plan's exact analytic replay of
/// the reference's measured counters.
#[allow(clippy::too_many_arguments)]
pub fn execute_hessian(
    plan: &HessianPlan,
    graph: &Graph,
    a: &Tensor,
    b_coef: Option<&[f64]>,
    c_coef: Option<f64>,
    x: &Tensor,
    panels: &PanelSet,
    slab: &mut Vec<f64>,
) -> HessianResult {
    execute_hessian_profiled(plan, graph, a, b_coef, c_coef, x, panels, slab, None)
}

/// [`execute_hessian`] with optional per-step profiling. With
/// `profiler: None` the extra cost is one `is_some()` branch per step and
/// zero allocation; the arithmetic (and thus the result bits) is identical
/// either way. When profiling, each phase records measured seconds beside
/// the plan's analytic per-phase charge, so the records sum exactly to
/// [`HessianPlan::cost`] — asserted by `rust/tests/observability.rs`.
#[allow(clippy::too_many_arguments)]
pub fn execute_hessian_profiled(
    plan: &HessianPlan,
    graph: &Graph,
    a: &Tensor,
    b_coef: Option<&[f64]>,
    c_coef: Option<f64>,
    x: &Tensor,
    panels: &PanelSet,
    slab: &mut Vec<f64>,
    mut profiler: Option<&mut StepProfiler>,
) -> HessianResult {
    assert_eq!(x.rank(), 2, "input must be [batch, N]");
    let n = plan.n;
    let batch = x.dims()[0];
    assert_eq!(x.dims()[1], n, "input dim mismatch");
    assert_eq!(a.dims()[0], n, "A must be N×N with N = input dim");
    assert_eq!(graph.len(), plan.node_count(), "plan/graph mismatch");
    let out_id = plan.out_id;
    assert_eq!(
        graph.node(out_id).dim,
        1,
        "Hessian baseline expects a scalar-output graph"
    );
    let need = plan.slab_len(batch);
    if slab.len() < need {
        slab.resize(need, 0.0);
    }
    let slab = &mut slab[..need];
    let dim = |j: usize| graph.node(j).dim;
    let fwd = |j: usize| block(plan.fwd_slot[j], n * dim(j), batch);
    let gbar = |j: usize| {
        debug_assert_ne!(plan.gbar_slot[j], usize::MAX, "gbar slot unassigned");
        block(plan.gbar_slot[j], n * dim(j), batch)
    };

    // (1) forward values (the schedule is the topological node order).
    let t0 = profiler.is_some().then(Instant::now);
    let values = graph.eval_all(x);
    if let (Some(p), Some(t0)) = (profiler.as_deref_mut(), t0) {
        // Value evaluation is uncharged in the reference cost model.
        p.record(usize::MAX, "values", t0.elapsed().as_secs_f64(), 0, 0);
    }

    // (2) forward Jacobian tangents (eq. 13) on the slab, schedule-driven.
    let seed = plan.identity_seed();
    for (si, step) in plan.steps.iter().enumerate() {
        let t0 = profiler.is_some().then(Instant::now);
        forward_node(
            plan, graph, seed, &values, batch, slab, step.node, &step.kind, panels,
        );
        if let StepKind::Linear {
            fused_act: Some(ai),
            ..
        } = &step.kind
        {
            forward_node(
                plan,
                graph,
                seed,
                &values,
                batch,
                slab,
                *ai,
                &StepKind::Activation,
                panels,
            );
        }
        if let (Some(p), Some(t0)) = (profiler.as_deref_mut(), t0) {
            let c = plan.fwd_step_costs[si];
            p.record(
                step.node,
                step_label(&step.kind),
                t0.elapsed().as_secs_f64(),
                c.muls * batch as u64,
                c.adds * batch as u64,
            );
        }
    }

    // (3) reverse adjoints (eq. 12) — [batch, d] buffers, no tangents.
    let t0 = profiler.is_some().then(Instant::now);
    let ones = Tensor::full(&[batch, 1], 1.0);
    let bw = backward(graph, &values, &ones, false);
    if let (Some(p), Some(t0)) = (profiler.as_deref_mut(), t0) {
        let c = plan.adjoint_cost_per_row;
        p.record(
            usize::MAX,
            "adjoint",
            t0.elapsed().as_secs_f64(),
            c.muls * batch as u64,
            c.adds * batch as u64,
        );
    }

    // (4) second-order reverse sweep (eq. 14) on the slab, reverse
    // schedule order (= reverse node order, fused steps expanded).
    let mut has_gbar = vec![false; graph.len()];
    {
        let (win, _ros) = carve1(slab, &gbar(out_id));
        win.fill(0.0);
    }
    has_gbar[out_id] = true;
    for j in (0..graph.len()).rev() {
        let node = graph.node(j);
        if matches!(node.op, Op::Input { .. }) {
            // Keep: its ∇v̄ is a block of Hessian rows, extracted below.
            continue;
        }
        let t0 = profiler.is_some().then(Instant::now);
        if !has_gbar[j] {
            // Node does not influence the output; nothing flows.
            let (win, _ros) = carve1(slab, &gbar(j));
            win.fill(0.0);
            has_gbar[j] = true;
        }
        let d = node.dim;
        let vbar_j = bw.adjoints[j].data();
        match &node.op {
            Op::Input { .. } => unreachable!(),
            Op::Linear { weight, .. } => {
                let p = node.inputs[0];
                let in_d = weight.dims()[1];
                let scr = scratch_window(plan, batch, n * in_d);
                {
                    let (win, ros) = carve1(slab, &scr);
                    let gj = rd(&ros, gbar(j));
                    kernels::hess_linear_reverse(weight, batch * n, gj, win);
                }
                merge_contrib(slab, &scr, &gbar(p), &mut has_gbar[p]);
            }
            Op::Activation { act } => {
                let p = node.inputs[0];
                let scr = scratch_window(plan, batch, n * d);
                {
                    let (win, ros) = carve1(slab, &scr);
                    let gj = rd(&ros, gbar(j));
                    let gp = rd(&ros, fwd(p));
                    kernels::hess_activation_reverse(
                        *act,
                        batch,
                        n,
                        d,
                        values[p].data(),
                        vbar_j,
                        gj,
                        gp,
                        win,
                    );
                }
                merge_contrib(slab, &scr, &gbar(p), &mut has_gbar[p]);
            }
            Op::Slice { start, len } => {
                let p = node.inputs[0];
                let pd = dim(p);
                let scr = scratch_window(plan, batch, n * pd);
                {
                    let (win, ros) = carve1(slab, &scr);
                    let gj = rd(&ros, gbar(j));
                    win.fill(0.0);
                    for r in 0..batch * n {
                        win[r * pd + start..r * pd + start + len]
                            .copy_from_slice(&gj[r * len..(r + 1) * len]);
                    }
                }
                merge_contrib(slab, &scr, &gbar(p), &mut has_gbar[p]);
            }
            Op::Add => {
                for &p in &node.inputs {
                    // contrib = ∇v̄^j verbatim.
                    let scr = scratch_window(plan, batch, n * d);
                    {
                        let (win, ros) = carve1(slab, &scr);
                        win.copy_from_slice(rd(&ros, gbar(j)));
                    }
                    merge_contrib(slab, &scr, &gbar(p), &mut has_gbar[p]);
                }
            }
            Op::Mul => {
                for (pi, &p) in node.inputs.iter().enumerate() {
                    let scr = scratch_window(plan, batch, n * d);
                    {
                        let (win, ros) = carve1(slab, &scr);
                        let gj = rd(&ros, gbar(j));
                        let pvals: Vec<&[f64]> =
                            node.inputs.iter().map(|&q| values[q].data()).collect();
                        let ptans: Vec<&[f64]> =
                            node.inputs.iter().map(|&q| rd(&ros, fwd(q))).collect();
                        kernels::hess_mul_reverse_parent(
                            batch, n, d, pi, &pvals, vbar_j, gj, &ptans, win,
                        );
                    }
                    merge_contrib(slab, &scr, &gbar(p), &mut has_gbar[p]);
                }
            }
            Op::SumReduce => {
                let p = node.inputs[0];
                let pd = dim(p);
                let scr = scratch_window(plan, batch, n * pd);
                {
                    let (win, ros) = carve1(slab, &scr);
                    let gj = rd(&ros, gbar(j));
                    for r in 0..batch * n {
                        let v = gj[r];
                        for c in win[r * pd..(r + 1) * pd].iter_mut() {
                            *c = v;
                        }
                    }
                }
                merge_contrib(slab, &scr, &gbar(p), &mut has_gbar[p]);
            }
            Op::Concat => {
                let mut off = 0usize;
                for &p in &node.inputs {
                    let pd = dim(p);
                    let scr = scratch_window(plan, batch, n * pd);
                    {
                        let (win, ros) = carve1(slab, &scr);
                        let gj = rd(&ros, gbar(j));
                        for r in 0..batch * n {
                            win[r * pd..(r + 1) * pd]
                                .copy_from_slice(&gj[r * d + off..r * d + off + pd]);
                        }
                    }
                    merge_contrib(slab, &scr, &gbar(p), &mut has_gbar[p]);
                    off += pd;
                }
            }
        }
        if let (Some(p), Some(t0)) = (profiler.as_deref_mut(), t0) {
            let c = plan.rev_node_costs[j];
            p.record(
                j,
                rev_label(&node.op),
                t0.elapsed().as_secs_f64(),
                c.muls * batch as u64,
                c.adds * batch as u64,
            );
        }
    }

    // Assemble the Hessian (+ contraction + lower-order terms) — one
    // profiled "contract" phase whose charge carries the `b`/`c` extras.
    let t_fin = profiler.is_some().then(Instant::now);

    // Assemble the Hessian from input-node ∇v̄ blocks.
    let mut hessian = Tensor::zeros(&[batch, n, n]);
    let mut off = 0usize;
    for &i in graph.input_ids() {
        let d = dim(i);
        if has_gbar[i] {
            let g = &slab[gbar(i)];
            for b in 0..batch {
                for k in 0..n {
                    let row = &g[(b * n + k) * d..(b * n + k + 1) * d];
                    hessian.data_mut()[(b * n + k) * n + off..(b * n + k) * n + off + d]
                        .copy_from_slice(row);
                }
            }
        }
        off += d;
    }

    // (5) contract with A (+ optional lower-order terms).
    let mut op_vals = Tensor::zeros(&[batch, 1]);
    let ad = a.data();
    for b in 0..batch {
        let hb = &hessian.data()[b * n * n..(b + 1) * n * n];
        let mut acc = 0.0;
        for idx in 0..n * n {
            acc += ad[idx] * hb[idx];
        }
        op_vals.set(b, 0, acc);
    }

    // Gradient from the eq. 12 adjoints at the input nodes (the reference
    // recomputes them via `input_gradient`; same deterministic sweep, same
    // bits — minus one redundant backward pass).
    let mut gradient = Tensor::zeros(&[batch, n]);
    let mut off = 0usize;
    for &i in graph.input_ids() {
        let d = dim(i);
        for b in 0..batch {
            gradient.row_mut(b)[off..off + d].copy_from_slice(bw.adjoints[i].row(b));
        }
        off += d;
    }
    if let Some(bv) = b_coef {
        for b in 0..batch {
            let extra: f64 = bv.iter().zip(gradient.row(b)).map(|(&c, &g)| c * g).sum();
            op_vals.set(b, 0, op_vals.at(b, 0) + extra);
        }
    }
    let values_out = values[out_id].clone();
    if let Some(c) = c_coef {
        for b in 0..batch {
            op_vals.set(b, 0, op_vals.at(b, 0) + c * values_out.at(b, 0));
        }
    }

    if let (Some(p), Some(t0)) = (profiler.as_deref_mut(), t_fin) {
        let c = plan.contract_cost_per_row;
        let mut muls = c.muls * batch as u64;
        if b_coef.is_some() {
            muls += (batch * n) as u64;
        }
        if c_coef.is_some() {
            muls += batch as u64;
        }
        p.record(
            usize::MAX,
            "contract",
            t0.elapsed().as_secs_f64(),
            muls,
            c.adds * batch as u64,
        );
    }

    HessianResult {
        values: values_out,
        gradient,
        hessian,
        operator_values: op_vals,
        cost: plan.cost(batch, b_coef.is_some(), c_coef.is_some()),
        peak_tangent_bytes: plan.peak_tangent_bytes(batch),
    }
}

/// Profile label for one reverse-sweep node visit.
fn rev_label(op: &Op) -> &'static str {
    match op {
        Op::Input { .. } => "rev:input",
        Op::Linear { .. } => "rev:linear",
        Op::Activation { .. } => "rev:activation",
        Op::Slice { .. } => "rev:slice",
        Op::Add => "rev:add",
        Op::Mul => "rev:mul",
        Op::SumReduce => "rev:sum_reduce",
        Op::Concat => "rev:concat",
    }
}

/// The first `units·batch` scalars of the contribution scratch block.
fn scratch_window(plan: &HessianPlan, batch: usize, units: usize) -> Range<usize> {
    assert!(units <= plan.scratch_len, "contribution scratch overflow");
    let lo = plan.scratch_slot * batch;
    lo..lo + units * batch
}

/// Merge the scratch contribution into a parent's `∇v̄` block: copy on the
/// first contribution, elementwise add thereafter (the reference path's
/// `accumulate`).
fn merge_contrib(slab: &mut [f64], scr: &Range<usize>, dst: &Range<usize>, has: &mut bool) {
    let (win, ros) = carve1(slab, dst);
    let src = rd(&ros, scr.start..scr.start + win.len());
    if *has {
        for (d, &s) in win.iter_mut().zip(src.iter()) {
            *d += s;
        }
    } else {
        win.copy_from_slice(src);
        *has = true;
    }
}

/// One node of the forward Jacobian sweep (eq. 13) on the slab — the same
/// per-op arithmetic `propagate_tangent` runs on owned tensors, via the
/// shared kernels.
#[allow(clippy::too_many_arguments)]
fn forward_node(
    plan: &HessianPlan,
    graph: &Graph,
    seed: &Tensor,
    values: &[Tensor],
    batch: usize,
    slab: &mut [f64],
    id: usize,
    kind: &StepKind,
    panels: &PanelSet,
) {
    let n = plan.n;
    let node = graph.node(id);
    let d = node.dim;
    let fwd = |j: usize| block(plan.fwd_slot[j], n * graph.node(j).dim, batch);
    let w = fwd(id);
    let (win, ros) = carve1(slab, &w);
    match &node.op {
        Op::Input { .. } => {
            let in_off = match kind {
                StepKind::Input { in_off } => *in_off,
                _ => unreachable!("input node scheduled as non-input step"),
            };
            for b in 0..batch {
                for k in 0..n {
                    let o = (b * n + k) * d;
                    win[o..o + d].copy_from_slice(&seed.row(k)[in_off..in_off + d]);
                }
            }
        }
        Op::Linear { weight, .. } => {
            let gemm = match kind {
                StepKind::Linear { gemm, .. } => *gemm,
                _ => unreachable!("linear node scheduled as non-linear step"),
            };
            let panel = panels.get(id).and_then(|pn| pn.as_ref());
            let p = node.inputs[0];
            let in_d = weight.dims()[1];
            let pg = rd(&ros, fwd(p));
            win.fill(0.0);
            matmul_nt_planned(pg, weight.data(), panel, gemm, win, batch * n, in_d, d);
        }
        Op::Activation { act } => {
            let p = node.inputs[0];
            let pg = rd(&ros, fwd(p));
            kernels::jac_activation(*act, batch, n, d, values[p].data(), pg, win);
        }
        Op::Slice { start, len } => {
            let p = node.inputs[0];
            let pd = graph.node(p).dim;
            let pg = rd(&ros, fwd(p));
            for r in 0..batch * n {
                win[r * len..(r + 1) * len]
                    .copy_from_slice(&pg[r * pd + start..r * pd + start + len]);
            }
        }
        Op::Add => {
            for (pi, &p) in node.inputs.iter().enumerate() {
                let pg = rd(&ros, fwd(p));
                if pi == 0 {
                    win.copy_from_slice(pg);
                } else {
                    for (dst, &sv) in win.iter_mut().zip(pg.iter()) {
                        *dst += sv;
                    }
                }
            }
        }
        Op::Mul => {
            let pvals: Vec<&[f64]> = node.inputs.iter().map(|&q| values[q].data()).collect();
            let ptans: Vec<&[f64]> = node.inputs.iter().map(|&q| rd(&ros, fwd(q))).collect();
            kernels::jac_mul(batch, n, d, &pvals, &ptans, win);
        }
        Op::SumReduce => {
            let p = node.inputs[0];
            let pd = graph.node(p).dim;
            let pg = rd(&ros, fwd(p));
            for r in 0..batch * n {
                win[r] = pg[r * pd..(r + 1) * pd].iter().sum::<f64>();
            }
        }
        Op::Concat => {
            let mut off = 0usize;
            for &p in &node.inputs {
                let pd = graph.node(p).dim;
                let pg = rd(&ros, fwd(p));
                for r in 0..batch * n {
                    win[r * d + off..r * d + off + pd]
                        .copy_from_slice(&pg[r * pd..(r + 1) * pd]);
                }
                off += pd;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{builder::random_layers, mlp_graph, Act};
    use crate::util::Xoshiro256;

    #[test]
    fn plan_is_batch_linear_and_keyed_by_structure() {
        let mut rng = Xoshiro256::new(61);
        let layers = random_layers(&[4, 9, 1], &mut rng);
        let layers_moved = random_layers(&[4, 9, 1], &mut rng);
        let g1 = mlp_graph(&layers, Act::Tanh);
        let g2 = mlp_graph(&layers_moved, Act::Tanh);
        let g3 = mlp_graph(&random_layers(&[4, 10, 1], &mut rng), Act::Tanh);
        assert_eq!(hessian_key(&g1), hessian_key(&g2), "values must not key");
        assert_ne!(hessian_key(&g1), hessian_key(&g3), "structure must key");
        let p = HessianPlan::compile(&g1);
        let c1 = p.cost(1, false, false);
        let c7 = p.cost(7, false, false);
        assert_eq!(c7.muls, 7 * c1.muls);
        assert_eq!(c7.adds, 7 * c1.adds);
        assert_eq!(p.peak_tangent_bytes(7), 7 * p.peak_tangent_bytes(1));
        assert_eq!(p.slab_len(7), 7 * p.slab_per_row());
        assert!(p.slab_per_row() > 0);
    }

    #[test]
    fn phase_costs_sum_to_plan_cost() {
        let mut rng = Xoshiro256::new(63);
        let g = mlp_graph(&random_layers(&[5, 11, 7, 1], &mut rng), Act::Tanh);
        let p = HessianPlan::compile(&g);
        let mut sum = p.contract_cost_per_row;
        sum.muls += p.adjoint_cost_per_row.muls;
        sum.adds += p.adjoint_cost_per_row.adds;
        for c in p.fwd_step_costs.iter().chain(p.rev_node_costs.iter()) {
            sum.muls += c.muls;
            sum.adds += c.adds;
        }
        assert_eq!(sum, p.cost_per_row);
        // Lower-order extras ride on top of the per-row total.
        let c = p.cost(3, true, true);
        assert_eq!(c.muls, 3 * p.cost_per_row.muls + 3 * p.input_dim() as u64 + 3);
        assert_eq!(c.adds, 3 * p.cost_per_row.adds);
    }

    #[test]
    fn cache_hits_on_structure() {
        let cache = HessianPlanCache::new();
        let mut rng = Xoshiro256::new(62);
        let layers = random_layers(&[3, 6, 1], &mut rng);
        let layers2 = random_layers(&[3, 6, 1], &mut rng);
        let a = cache.get_or_compile(&mlp_graph(&layers, Act::Sin));
        let b = cache.get_or_compile(&mlp_graph(&layers2, Act::Sin));
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(cache.stats().misses, 1);
    }
}
