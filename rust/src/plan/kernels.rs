//! **Shared op kernels** — the single home of every numeric propagation
//! rule the engines execute, parameterized by a *storage policy*: callers
//! resolve their storage (static slab slots, arena-recycled tensors, or
//! retained tape tensors) into flat `f64` slices and the kernels do the
//! arithmetic. One definition, N storage policies:
//!
//! * the **slab executor** ([`crate::plan::exec::execute_dof`]) passes
//!   windows of the per-shard slab;
//! * the **retain-all tape executor** ([`crate::plan::exec::execute_tape`])
//!   passes owned tensors that outlive the pass;
//! * the **reference interpreter**
//!   ([`crate::autodiff::DofEngine::compute_with_arena`]) passes
//!   arena-recycled buffers — it stays the differential-testing oracle, but
//!   an oracle that *shares* these kernels, so a numeric fix to e.g. the
//!   `Mul` cross term lands in exactly one place;
//! * the **Hessian baseline** shares the forward-Jacobian kernels
//!   ([`jac_activation`], [`jac_mul`]) and the eq. 14 reverse kernels
//!   ([`hess_activation_reverse`], [`hess_mul_reverse_parent`],
//!   [`hess_linear_reverse`]) between its program-scheduled slab executor
//!   ([`crate::plan::hessian`]) and the retained reference path
//!   ([`crate::autodiff::HessianEngine::compute_reference`]);
//! * the **jet subsystem**'s per-component kernels ([`compose5`],
//!   [`cauchy5`]) live here too, shared by its slab executor and
//!   interpreter.
//!
//! Layout contract (DOF tuple kernels): value/scalar streams are flat
//! `[batch, d]` row-major slices; tangents are flat `[batch·t, d]` with row
//! index `b·t + kk`; `active[kk]` is the global `L`-row index of tangent
//! row `kk` (the §3.2 active set — the full `0..r` identity in dense mode),
//! and `signs` is the full `D` diagonal indexed by those global rows.
//! Kernels either fully overwrite their destinations or zero-fill them
//! first, so callers may hand them non-zeroed scratch.
//!
//! FLOP accounting stays with the callers (the interpreter accumulates at
//! runtime, the programs carry exact analytic counts) — the kernels are
//! pure arithmetic, which is what keeps one definition serving executors
//! with different accounting conventions.
//!
//! Bit-identity: for a fixed op the kernels perform the same floating-point
//! operations in the same order regardless of the storage policy, so the
//! equivalence suites (`plan_equivalence.rs`, `jet_equivalence.rs`,
//! `cross_engine_fuzz.rs`) assert planned ≡ interpreter *bitwise* — by
//! construction, not by coincidence.
//!
//! Vectorization: every elementwise inner loop runs through the chunked
//! lane helpers ([`crate::tensor::lanes`] — explicit 8-wide stable-Rust
//! chunks with scalar tails, per-element expressions unchanged, so the
//! rewrite is bit-preserving by construction), and the Linear GEMM
//! dispatches on the plan-time [`GemmPlan`] recorded in the schedule
//! (optionally over a caller-packed [`PackedPanel`]) instead of a per-call
//! row-count branch. `rust/tests/simd_tails.rs` pins the chunked kernels
//! against retained scalar references at awkward widths.

use crate::graph::Act;
use crate::tensor::{lanes, matmul_into, matmul_nt_planned, GemmPlan, PackedPanel, Tensor};

// ---- DOF tuple kernels (eqs. 7–9) ----------------------------------------

/// Seed an input node's `(v, s, g)` tuple: `v` from the batch rows of `x`
/// at flat-input offset `in_off`, `s` from the first-order coefficients
/// `b` (zero when absent), `g` from the active rows of `L`.
#[allow(clippy::too_many_arguments)]
pub(crate) fn input_seed(
    x: &Tensor,
    in_off: usize,
    d: usize,
    batch: usize,
    b_coef: Option<&[f64]>,
    l: &Tensor,
    active: &[usize],
    v: &mut [f64],
    s: &mut [f64],
    g: &mut [f64],
) {
    let t = active.len();
    debug_assert_eq!(v.len(), batch * d);
    debug_assert_eq!(s.len(), batch * d);
    debug_assert_eq!(g.len(), batch * t * d);
    for b in 0..batch {
        v[b * d..(b + 1) * d].copy_from_slice(&x.row(b)[in_off..in_off + d]);
    }
    match b_coef {
        Some(bv) => {
            for b in 0..batch {
                s[b * d..(b + 1) * d].copy_from_slice(&bv[in_off..in_off + d]);
            }
        }
        None => s.fill(0.0),
    }
    for b in 0..batch {
        for (kk, &k) in active.iter().enumerate() {
            let o = (b * t + kk) * d;
            g[o..o + d].copy_from_slice(&l.row(k)[in_off..in_off + d]);
        }
    }
}

/// The affine node — one half of the **fused `Linear → Activation`** step
/// (the other half is [`activation_forward`]; the schedule-level pairing is
/// shared via [`crate::plan::build_schedule`]).
///
/// All three streams are right-products by `Wᵀ`: stack `[v; s; G]` of the
/// parent into `stacked` (`batch·(t+2)` rows of `in_d`), run ONE GEMM into
/// the zero-filled `gout`, scatter back into the node's streams, and add
/// the bias on the value rows only.
///
/// The GEMM runs the micro-kernel `gemm` recorded at plan time (both forms
/// are bit-identical — see [`crate::tensor::matmul_nt_planned`]); `panel`
/// is the weight's pre-packed `Bᵀ` when the engine packed one for this
/// call, `None` on interpreter/tape paths (same bits either way).
#[allow(clippy::too_many_arguments)]
pub(crate) fn linear_forward(
    weight: &Tensor,
    bias: &[f64],
    gemm: GemmPlan,
    panel: Option<&PackedPanel>,
    batch: usize,
    t: usize,
    pv: &[f64],
    ps: &[f64],
    pg: &[f64],
    stacked: &mut [f64],
    gout: &mut [f64],
    v: &mut [f64],
    s: &mut [f64],
    g: &mut [f64],
) {
    let (out_d, in_d) = (weight.dims()[0], weight.dims()[1]);
    let rows = batch * (t + 2);
    debug_assert_eq!(stacked.len(), rows * in_d);
    debug_assert_eq!(gout.len(), rows * out_d);
    stacked[..batch * in_d].copy_from_slice(pv);
    stacked[batch * in_d..2 * batch * in_d].copy_from_slice(ps);
    stacked[2 * batch * in_d..].copy_from_slice(pg);
    gout.fill(0.0);
    matmul_nt_planned(stacked, weight.data(), panel, gemm, gout, rows, in_d, out_d);
    v.copy_from_slice(&gout[..batch * out_d]);
    s.copy_from_slice(&gout[batch * out_d..2 * batch * out_d]);
    g.copy_from_slice(&gout[2 * batch * out_d..]);
    for b in 0..batch {
        lanes::add_assign(&mut v[b * out_d..(b + 1) * out_d], bias);
    }
}

/// The elementwise node — the other half of the fused
/// `Linear → Activation` step: `v = σ(h)`, then one fused pass per tangent
/// row that reads `g` once, accumulates the signed square into the eq. 9
/// quadratic and writes the `σ'`-scaled tangent, and finally the scalar
/// stream `s = σ''(h)·quad + σ'(h)·s_parent`.
#[allow(clippy::too_many_arguments)]
pub(crate) fn activation_forward(
    act: Act,
    signs: &[f64],
    active: &[usize],
    batch: usize,
    d: usize,
    h: &[f64],
    ps: &[f64],
    pg: &[f64],
    v: &mut [f64],
    s: &mut [f64],
    g: &mut [f64],
) {
    let t = active.len();
    debug_assert_eq!(g.len(), batch * t * d);
    for (dst, &src) in v.iter_mut().zip(h.iter()) {
        *dst = act.f(src);
    }
    // σ' and σ'' are evaluated once per (batch, component) — transcendental
    // calls don't lane-ize; everything downstream of them does.
    let mut df = vec![0.0; d];
    let mut d2 = vec![0.0; d];
    let mut quad = vec![0.0; d];
    for b in 0..batch {
        let hrow = &h[b * d..(b + 1) * d];
        for (dv, &hv) in df.iter_mut().zip(hrow.iter()) {
            *dv = act.df(hv);
        }
        quad.fill(0.0);
        for (kk, &k) in active.iter().enumerate() {
            let sign = signs[k];
            let src = &pg[(b * t + kk) * d..(b * t + kk + 1) * d];
            let dst = &mut g[(b * t + kk) * d..(b * t + kk + 1) * d];
            lanes::scaled_sq_acc(&mut quad, sign, src);
            lanes::mul_into(dst, &df, src);
        }
        for (dv, &hv) in d2.iter_mut().zip(hrow.iter()) {
            *dv = act.d2f(hv);
        }
        let psr = &ps[b * d..(b + 1) * d];
        let sp = &mut s[b * d..(b + 1) * d];
        lanes::mul_mul_add_into(sp, &d2, &quad, &df, psr);
    }
}

/// The Hadamard product node — the eq. 9 product rule, including the
/// **`Mul` cross term** `2·Σ_{p<q} (Π_{r≠p,q} v^r) ⊙ (g^pᵀ D g^q)`.
///
/// `pvals`/`psums` are the parents' value/scalar streams; `aligned[pi]` is
/// parent `pi`'s tangent already expanded onto this node's union active set
/// (zero-filled missing rows) — union alignment is storage policy, the
/// product rule is not. Fully overwrites `v` and zero-fills `s`/`g` before
/// accumulating.
#[allow(clippy::too_many_arguments)]
pub(crate) fn mul_forward(
    signs: &[f64],
    active: &[usize],
    batch: usize,
    d: usize,
    pvals: &[&[f64]],
    psums: &[&[f64]],
    aligned: &[&[f64]],
    v: &mut [f64],
    s: &mut [f64],
    g: &mut [f64],
) {
    let t = active.len();
    let k = pvals.len();
    debug_assert_eq!(psums.len(), k);
    debug_assert_eq!(aligned.len(), k);
    debug_assert_eq!(g.len(), batch * t * d);

    // Value chain v = Π_p v^p.
    v.copy_from_slice(pvals[0]);
    for pv in &pvals[1..] {
        lanes::mul_assign(v, pv);
    }
    s.fill(0.0);
    g.fill(0.0);

    let mut coef = vec![1.0; d];
    let mut coef2 = vec![1.0; d];
    let mut cross = vec![0.0; d];
    for b in 0..batch {
        for pi in 0..k {
            // Leave-one-out coefficient Π_{q≠pi} v^q.
            coef.fill(1.0);
            for (qi, pv) in pvals.iter().enumerate() {
                if qi != pi {
                    lanes::mul_assign(&mut coef, &pv[b * d..(b + 1) * d]);
                }
            }
            // Tangent stream (eq. 8 term).
            for kk in 0..t {
                let src = &aligned[pi][(b * t + kk) * d..(b * t + kk + 1) * d];
                let dst = &mut g[(b * t + kk) * d..(b * t + kk + 1) * d];
                lanes::mul_acc(dst, &coef, src);
            }
            // Scalar stream, first-order part.
            {
                let psr = &psums[pi][b * d..(b + 1) * d];
                let srow = &mut s[b * d..(b + 1) * d];
                lanes::mul_acc(srow, &coef, psr);
            }
            // Cross term over unordered pairs (pi, qi).
            for qi in (pi + 1)..k {
                coef2.fill(1.0);
                for (ri, pv) in pvals.iter().enumerate() {
                    if ri != pi && ri != qi {
                        lanes::mul_assign(&mut coef2, &pv[b * d..(b + 1) * d]);
                    }
                }
                cross.fill(0.0);
                for (kk, &kglob) in active.iter().enumerate() {
                    let sign = signs[kglob];
                    let gp = &aligned[pi][(b * t + kk) * d..(b * t + kk + 1) * d];
                    let gq = &aligned[qi][(b * t + kk) * d..(b * t + kk + 1) * d];
                    lanes::scaled_mul_acc(&mut cross, sign, gp, gq);
                }
                let srow = &mut s[b * d..(b + 1) * d];
                lanes::scaled_mul_acc(srow, 2.0, &coef2, &cross);
            }
        }
    }
}

// ---- forward-Jacobian kernels (eq. 13) -----------------------------------
//
// Width-t tangent propagation without the (v, s) streams — the Hessian
// baseline's forward sweep, shared by `autodiff::forward_jacobian::
// propagate_tangent` (owned tensors) and `plan::hessian` (slab slots).
// Linear is a plain `G Wᵀ` GEMM dispatched through the plan-recorded
// [`crate::tensor::matmul_nt_planned`] (Dot or packed-panel AXPY — both
// `==`-identical by the summation-order contract);
// Slice/Add/SumReduce/Concat are pure copies/sums.

/// `G' = σ'(h) ⊙ G`, full assignment (σ' evaluated once per (batch,
/// component) and reused across the `t` tangent rows — same values, same
/// products, so bitwise identical to the per-row evaluation it replaced).
pub(crate) fn jac_activation(
    act: Act,
    batch: usize,
    t: usize,
    d: usize,
    h: &[f64],
    pg: &[f64],
    g: &mut [f64],
) {
    debug_assert_eq!(g.len(), batch * t * d);
    let mut df = vec![0.0; d];
    for b in 0..batch {
        let hrow = &h[b * d..(b + 1) * d];
        for (dv, &hv) in df.iter_mut().zip(hrow.iter()) {
            *dv = act.df(hv);
        }
        for kk in 0..t {
            let src = &pg[(b * t + kk) * d..(b * t + kk + 1) * d];
            let dst = &mut g[(b * t + kk) * d..(b * t + kk + 1) * d];
            lanes::mul_into(dst, src, &df);
        }
    }
}

/// `G' = Σ_p (Π_{q≠p} v^q) ⊙ G^p` — the first-order product rule on
/// full-width tangents. Zero-fills `g` before accumulating.
pub(crate) fn jac_mul(
    batch: usize,
    t: usize,
    d: usize,
    pvals: &[&[f64]],
    ptangents: &[&[f64]],
    g: &mut [f64],
) {
    let k = pvals.len();
    debug_assert_eq!(ptangents.len(), k);
    debug_assert_eq!(g.len(), batch * t * d);
    g.fill(0.0);
    let mut coef = vec![1.0; d];
    for pi in 0..k {
        for b in 0..batch {
            coef.fill(1.0);
            for (qi, pv) in pvals.iter().enumerate() {
                if qi != pi {
                    lanes::mul_assign(&mut coef, &pv[b * d..(b + 1) * d]);
                }
            }
            for kk in 0..t {
                let src = &ptangents[pi][(b * t + kk) * d..(b * t + kk + 1) * d];
                let dst = &mut g[(b * t + kk) * d..(b * t + kk + 1) * d];
                lanes::mul_acc(dst, &coef, src);
            }
        }
    }
}

// ---- Hessian eq. 14 reverse kernels --------------------------------------
//
// Per-node contributions ∇v̄^j → ∇v̄^p of the second-order reverse sweep.
// Each kernel fully assigns `contrib` (the caller merges it into the
// parent's accumulator: copy on first contribution, add thereafter —
// mirroring the reference path's `accumulate`).

/// Linear: `contrib = ∇v̄^j · W` (no second-derivative term).
pub(crate) fn hess_linear_reverse(
    weight: &Tensor,
    rows: usize,
    gbar_j: &[f64],
    contrib: &mut [f64],
) {
    let (out_d, in_d) = (weight.dims()[0], weight.dims()[1]);
    debug_assert_eq!(gbar_j.len(), rows * out_d);
    debug_assert_eq!(contrib.len(), rows * in_d);
    contrib.fill(0.0);
    matmul_into(gbar_j, weight.data(), contrib, rows, out_d, in_d);
}

/// Activation: `contrib = σ'(h) ⊙ ∇v̄^j + (σ''(h)·v̄^j) ⊙ ∇v^p` — the
/// `|T|`-term of eq. 14 (`∇v^p` is the parent's forward tangent, still
/// live across the reverse sweep).
#[allow(clippy::too_many_arguments)]
pub(crate) fn hess_activation_reverse(
    act: Act,
    batch: usize,
    t: usize,
    d: usize,
    h: &[f64],
    vbar: &[f64],
    gbar_j: &[f64],
    gp: &[f64],
    contrib: &mut [f64],
) {
    debug_assert_eq!(contrib.len(), batch * t * d);
    for b in 0..batch {
        let hrow = &h[b * d..(b + 1) * d];
        let coef1: Vec<f64> = hrow.iter().map(|&v| act.df(v)).collect();
        let coef2: Vec<f64> = hrow
            .iter()
            .zip(&vbar[b * d..(b + 1) * d])
            .map(|(&hv, &vb)| act.d2f(hv) * vb)
            .collect();
        for kk in 0..t {
            let gj = &gbar_j[(b * t + kk) * d..(b * t + kk + 1) * d];
            let gpt = &gp[(b * t + kk) * d..(b * t + kk + 1) * d];
            let dst = &mut contrib[(b * t + kk) * d..(b * t + kk + 1) * d];
            lanes::mul_mul_add_into(dst, &coef1, gj, &coef2, gpt);
        }
    }
}

/// Mul, contribution to parent `pi`:
/// `contrib = (Π_{q≠pi} v^q) ⊙ ∇v̄^j + Σ_{q≠pi} (Π_{r≠pi,q} v^r · v̄^j) ⊙ ∇v^q`
/// — the Hessian-side cross term (`∇v^q` are the parents' forward
/// tangents).
#[allow(clippy::too_many_arguments)]
pub(crate) fn hess_mul_reverse_parent(
    batch: usize,
    t: usize,
    d: usize,
    pi: usize,
    pvals: &[&[f64]],
    vbar: &[f64],
    gbar_j: &[f64],
    ptangents: &[&[f64]],
    contrib: &mut [f64],
) {
    let k = pvals.len();
    debug_assert_eq!(contrib.len(), batch * t * d);
    let mut coefp = vec![1.0; d];
    let mut coefpq = vec![1.0; d];
    let mut scal = vec![0.0; d];
    for b in 0..batch {
        coefp.fill(1.0);
        for (qi, pv) in pvals.iter().enumerate() {
            if qi != pi {
                lanes::mul_assign(&mut coefp, &pv[b * d..(b + 1) * d]);
            }
        }
        for kk in 0..t {
            let gj = &gbar_j[(b * t + kk) * d..(b * t + kk + 1) * d];
            let dst = &mut contrib[(b * t + kk) * d..(b * t + kk + 1) * d];
            lanes::mul_into(dst, &coefp, gj);
        }
        for qi in 0..k {
            if qi == pi {
                continue;
            }
            coefpq.fill(1.0);
            for (ri, pv) in pvals.iter().enumerate() {
                if ri != pi && ri != qi {
                    lanes::mul_assign(&mut coefpq, &pv[b * d..(b + 1) * d]);
                }
            }
            lanes::mul_into(&mut scal, &coefpq, &vbar[b * d..(b + 1) * d]);
            for kk in 0..t {
                let gqt = &ptangents[qi][(b * t + kk) * d..(b * t + kk + 1) * d];
                let dst = &mut contrib[(b * t + kk) * d..(b * t + kk + 1) * d];
                lanes::mul_acc(dst, &scal, gqt);
            }
        }
    }
}

// ---- jet per-component kernels (Taylor mode) -----------------------------

/// Faà di Bruno composition of σ over one scalar jet: `a[0..=k]` are the
/// input Taylor coefficients (`a[0]` the pre-activation value), returns the
/// output coefficients. Entries above `k` are ignored.
///
/// For `k ≥ 3` the caller must have validated σ via
/// [`crate::jet::validate_graph`] (`d3f`/`d4f` return `Some`).
#[inline]
pub(crate) fn compose5(act: Act, k: usize, a: &[f64; 5]) -> [f64; 5] {
    let mut y = [0.0; 5];
    let h = a[0];
    y[0] = act.f(h);
    let d1 = act.df(h);
    y[1] = d1 * a[1];
    if k >= 2 {
        let d2 = act.d2f(h);
        y[2] = d1 * a[2] + 0.5 * d2 * a[1] * a[1];
        if k >= 3 {
            let d3 = act.d3f(h).expect("validated: σ''' available");
            y[3] = d1 * a[3]
                + d2 * a[1] * a[2]
                + (d3 * (1.0 / 6.0)) * a[1] * a[1] * a[1];
            if k >= 4 {
                let d4 = act.d4f(h).expect("validated: σ'''' available");
                y[4] = d1 * a[4]
                    + d2 * (a[1] * a[3] + 0.5 * a[2] * a[2])
                    + (0.5 * d3) * a[1] * a[1] * a[2]
                    + (d4 * (1.0 / 24.0)) * a[1] * a[1] * a[1] * a[1];
            }
        }
    }
    y
}

/// Cauchy (truncated Taylor) product of two scalar jets:
/// `out[m] = Σ_{i≤m} a[i]·b[m−i]`, ascending `i`.
#[inline]
pub(crate) fn cauchy5(k: usize, a: &[f64; 5], b: &[f64; 5]) -> [f64; 5] {
    let mut out = [0.0; 5];
    for m in 0..=k {
        let mut acc = 0.0;
        for i in 0..=m {
            acc += a[i] * b[m - i];
        }
        out[m] = acc;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    /// mul_forward against a hand-rolled 2-parent scalar case:
    /// v = v₁v₂, g = v₂g₁ + v₁g₂, s = v₂s₁ + v₁s₂ + 2·Σ d_k g₁g₂.
    #[test]
    fn mul_kernel_matches_closed_form_two_parents() {
        let signs = [1.0, -1.0];
        let active = [0usize, 1];
        let (batch, d) = (1usize, 1usize);
        let (v1, v2) = (2.0, 3.0);
        let (s1, s2) = (0.5, -0.25);
        let g1 = [0.7, -0.2];
        let g2 = [0.1, 0.4];
        let pvals: Vec<&[f64]> = vec![&[v1], &[v2]];
        let psums: Vec<&[f64]> = vec![&[s1], &[s2]];
        let aligned: Vec<&[f64]> = vec![&g1, &g2];
        let mut v = [0.0];
        let mut s = [7.0]; // stale scratch; kernel must zero
        let mut g = [7.0, 7.0];
        mul_forward(
            &signs, &active, batch, d, &pvals, &psums, &aligned, &mut v, &mut s, &mut g,
        );
        assert_eq!(v[0], v1 * v2);
        assert_eq!(g[0], v2 * g1[0] + v1 * g2[0]);
        assert_eq!(g[1], v2 * g1[1] + v1 * g2[1]);
        let cross = 1.0 * g1[0] * g2[0] + (-1.0) * g1[1] * g2[1];
        let want_s = v2 * s1 + v1 * s2 + 2.0 * cross;
        assert!((s[0] - want_s).abs() < 1e-15, "{} vs {want_s}", s[0]);
    }

    /// activation_forward against the closed-form eq. 9 rule for σ = square.
    #[test]
    fn activation_kernel_matches_closed_form() {
        let signs = [1.0];
        let active = [0usize];
        let (batch, d) = (1usize, 2usize);
        let h = [0.5, -1.5];
        let ps = [0.3, 0.6];
        let pg = [2.0, -0.5];
        let mut v = [0.0; 2];
        let mut s = [0.0; 2];
        let mut g = [0.0; 2];
        activation_forward(
            Act::Square,
            &signs,
            &active,
            batch,
            d,
            &h,
            &ps,
            &pg,
            &mut v,
            &mut s,
            &mut g,
        );
        for c in 0..2 {
            assert_eq!(v[c], h[c] * h[c]);
            assert_eq!(g[c], 2.0 * h[c] * pg[c]);
            // s = σ''·g² + σ'·s_p = 2g² + 2h·s_p.
            let want = 2.0 * pg[c] * pg[c] + 2.0 * h[c] * ps[c];
            assert!((s[c] - want).abs() < 1e-15);
        }
    }

    /// hess_mul_reverse_parent on a 2-parent product: the contribution to
    /// parent 0 is v² ⊙ ∇v̄ + v̄ ⊙ ∇v¹.
    #[test]
    fn hess_mul_reverse_matches_closed_form() {
        let (batch, t, d) = (1usize, 2usize, 1usize);
        let pvals: Vec<&[f64]> = vec![&[2.0], &[3.0]];
        let vbar = [0.5];
        let gbar_j = [1.0, -1.0];
        let g0 = [0.1, 0.2];
        let g1 = [0.3, 0.4];
        let ptangents: Vec<&[f64]> = vec![&g0, &g1];
        let mut contrib = [0.0; 2];
        hess_mul_reverse_parent(
            batch, t, d, 0, &pvals, &vbar, &gbar_j, &ptangents, &mut contrib,
        );
        for kk in 0..2 {
            let want = 3.0 * gbar_j[kk] + 0.5 * g1[kk];
            assert!((contrib[kk] - want).abs() < 1e-15);
        }
    }
}
