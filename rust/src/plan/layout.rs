//! Static slab layout: a compile-time free-list allocator that maps every
//! node's `(v, s, g)` tuple — and every step's scratch — to a fixed offset
//! in one contiguous per-shard slab.
//!
//! Offsets are assigned in **per-row scalar units** by replaying the
//! program schedule against the liveness table (`frees_at`, eq. 24): a
//! node's interval is allocated at its step and returned to the free list
//! at its last consumer, exactly mirroring the runtime alloc/free sequence
//! the interpreter used to drive the [`crate::autodiff::PeakTracker`].
//! Because every buffer's size is `per_row_size × batch` and the slab is
//! scaled the same way at execution time, interval disjointness in per-row
//! units implies disjointness for any batch size — the layout is compiled
//! once and reused for every batch.
//!
//! The allocator is first-fit with gap coalescing: deterministic (the
//! layout is part of the program, so executions are reproducible) and tight
//! enough that the slab high-water mark tracks the liveness peak.

/// First-fit free-list allocator over a growable address space.
#[derive(Debug, Default)]
pub struct SlabLayout {
    /// Sorted, disjoint, coalesced `(offset, len)` gaps.
    gaps: Vec<(usize, usize)>,
    /// High-water mark: total per-row slab length required.
    len: usize,
}

impl SlabLayout {
    pub fn new() -> Self {
        Self::default()
    }

    /// Allocate `size` units: smallest-offset gap that fits, else extend.
    pub fn alloc(&mut self, size: usize) -> usize {
        if size == 0 {
            return 0;
        }
        for i in 0..self.gaps.len() {
            let (off, glen) = self.gaps[i];
            if glen >= size {
                if glen == size {
                    self.gaps.remove(i);
                } else {
                    self.gaps[i] = (off + size, glen - size);
                }
                return off;
            }
        }
        let off = self.len;
        self.len += size;
        off
    }

    /// Return `[off, off+size)` to the free list, coalescing neighbors.
    pub fn free(&mut self, off: usize, size: usize) {
        if size == 0 {
            return;
        }
        let pos = self.gaps.partition_point(|&(o, _)| o < off);
        self.gaps.insert(pos, (off, size));
        if pos + 1 < self.gaps.len()
            && self.gaps[pos].0 + self.gaps[pos].1 == self.gaps[pos + 1].0
        {
            self.gaps[pos].1 += self.gaps[pos + 1].1;
            self.gaps.remove(pos + 1);
        }
        if pos > 0 && self.gaps[pos - 1].0 + self.gaps[pos - 1].1 == self.gaps[pos].0 {
            self.gaps[pos - 1].1 += self.gaps[pos].1;
            self.gaps.remove(pos);
        }
    }

    /// Total per-row slab length required by every allocation so far.
    pub fn high_water(&self) -> usize {
        self.len
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_extends_then_reuses() {
        let mut l = SlabLayout::new();
        let a = l.alloc(10);
        let b = l.alloc(5);
        assert_eq!((a, b), (0, 10));
        assert_eq!(l.high_water(), 15);
        l.free(a, 10);
        // Smaller request carves the front of the freed gap.
        let c = l.alloc(4);
        assert_eq!(c, 0);
        // Remaining gap [4, 10) serves the next fit; no growth.
        let d = l.alloc(6);
        assert_eq!(d, 4);
        assert_eq!(l.high_water(), 15);
    }

    #[test]
    fn free_coalesces_adjacent_gaps() {
        let mut l = SlabLayout::new();
        let a = l.alloc(8);
        let b = l.alloc(8);
        let c = l.alloc(8);
        l.free(a, 8);
        l.free(c, 8);
        l.free(b, 8); // middle free must merge all three
        let big = l.alloc(24);
        assert_eq!(big, 0);
        assert_eq!(l.high_water(), 24);
    }

    #[test]
    fn zero_size_is_noop() {
        let mut l = SlabLayout::new();
        assert_eq!(l.alloc(0), 0);
        l.free(0, 0);
        assert_eq!(l.high_water(), 0);
    }

    #[test]
    fn interleaved_lifetimes_stay_disjoint() {
        // Simulate a chain: each step allocates, frees the predecessor.
        let mut l = SlabLayout::new();
        let mut prev: Option<(usize, usize)> = None;
        let mut peak = 0usize;
        for step in 0..50 {
            let size = 16 + (step % 3) * 8;
            let off = l.alloc(size);
            if let Some((po, ps)) = prev.take() {
                // Live intervals must not overlap.
                assert!(off + size <= po || po + ps <= off || off >= po + ps);
                l.free(po, ps);
            }
            prev = Some((off, size));
            peak = peak.max(l.high_water());
        }
        // Steady-state chain should not grow the slab unboundedly.
        assert!(l.high_water() <= 2 * (16 + 16 + 24));
    }
}
