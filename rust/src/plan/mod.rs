//! Compile-once operator programs: the planned execution layer under every
//! DOF engine.
//!
//! Everything the eq. 7–9 propagation needs that is *static per
//! (architecture, operator)* is derived once here and reused for every
//! batch:
//!
//! * **schedule** — the topological node walk with `Linear → Activation`
//!   pairs fused into single steps (the MLP hot path dispatches once per
//!   layer instead of twice);
//! * **liveness** — the `τ(i)` table (eq. 24) and, from it, a **static
//!   buffer-slot assignment**: each node's `(v, s, g)` tuple is mapped to a
//!   fixed offset in one contiguous per-shard slab
//!   ([`layout::SlabLayout`]), replacing the per-call
//!   [`crate::autodiff::TangentArena`] lookups on the hot path while
//!   keeping the [`crate::autodiff::PeakTracker`] numbers identical (the
//!   peak is replayed analytically from the same alloc/free event order);
//! * **§3.2 active tangent rows** — per-node active-row sets precomputed by
//!   a structural support propagation (bitsets of possibly-nonzero
//!   components pushed through the graph), so the per-call rescans of `L`
//!   at input nodes and the runtime zero-row compaction at slice nodes
//!   disappear from execution;
//! * **micro-kernel selection** — each fused `Linear → Activation` step
//!   records the [`GemmPlan`] its stacked GEMM should run (`Dot` vs
//!   `PackedAxpy`, serial vs parallel-eligible), chosen at compile time
//!   from the batch-invariant per-item shape instead of branching on row
//!   counts inside every GEMM call (see [`crate::tensor::matmul_nt_planned`]);
//! * **analytic costs** — exact per-row FLOP counts and peak tangent bytes
//!   (both are exactly linear in the batch), so benches can report them
//!   without executing, plus the Appendix B/D closed-form models.
//!
//! A program is **shard-invariant**: it depends only on the graph
//! structure, the `L` zero pattern, and the options — never on the batch
//! size or thread count — so `compute_sharded` compiles once and executes
//! the same program on every shard (the PR 1 determinism contract holds by
//! construction). Programs are value-independent (weight *values* may
//! change under a fixed zero pattern, as in training), which is what makes
//! the keyed [`cache::PlanCache`] effective for the PINN trainer.

pub mod cache;
pub mod exec;
pub mod hessian;
pub(crate) mod kernels;
pub mod layout;

pub use cache::{global_cache, PlanCache, PlanCacheStats};

use std::sync::{Arc, OnceLock};

use crate::autodiff::flops::{graph_counts, CostModel, GraphCounts};
use crate::autodiff::Cost;
use crate::graph::{Act, Graph, Op};
use crate::linalg::LdlDecomposition;
use crate::tensor::{GemmForm, GemmPlan, PackedPanel};

use layout::SlabLayout;

/// Compile options — part of the cache key.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct PlanOptions {
    /// Exploit §3.2 active-tangent-row sparsity (compile-time row pruning).
    /// Off: every node carries the full rank-`r` tangent (the ablation the
    /// engines expose as [`crate::autodiff::DofEngine::dense`]).
    pub sparsity: bool,
    /// Whether the zeroth-order `c·φ` term participates (affects the exact
    /// FLOP count of the output step).
    pub lower_order_c: bool,
}

/// Cache key for a compiled program. The fingerprint hashes the graph
/// *structure* (op kinds, dims, wiring, weight zero patterns, activation
/// kinds) and the operator's `L` zero pattern plus signs — not the weight
/// values — so training steps that only move weight values reuse the plan.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct PlanKey {
    pub fingerprint: u64,
    pub nodes: usize,
    pub n: usize,
    pub rank: usize,
    pub sparsity: bool,
    pub lower_order_c: bool,
}

/// One executable step of the schedule.
#[derive(Debug, Clone)]
pub enum StepKind {
    /// Seed an input node from the batch rows and `L` (flat-input offset
    /// precomputed).
    Input { in_off: usize },
    /// Affine node; `fused_act` is the id of the following activation node
    /// when the pair was fused into one step, `gemm` the micro-kernel the
    /// compiler selected for this step's stacked GEMM (batch-invariant —
    /// chosen from the per-item row count `t + 2`, never the batch; both
    /// forms are bit-identical, see [`crate::tensor::matmul_nt_planned`]).
    Linear {
        fused_act: Option<usize>,
        gemm: GemmPlan,
    },
    Activation,
    Slice,
    Add,
    Mul,
    SumReduce,
    Concat,
}

/// A scheduled step materializing graph node `node` (for fused steps, the
/// Linear node; the activation id lives in the kind).
#[derive(Debug, Clone)]
pub struct Step {
    pub node: usize,
    pub kind: StepKind,
}

/// Per-node compiled facts.
#[derive(Debug, Clone)]
pub struct NodePlan {
    /// Node output dimension.
    pub dim: usize,
    /// Global (row-of-`L`) indices of the node's active tangent rows,
    /// sorted. `t = active.len()` is the node's tangent width.
    pub active: Vec<usize>,
    /// Per-row slab offset of the node's contiguous `[v | s | g]` block
    /// (`(t + 2) · dim` per-row scalars).
    pub slot: usize,
    /// Per-row slab offset/length of the node's step scratch (stacked GEMM
    /// buffers for Linear, union-aligned tangents for Mul); 0-length when
    /// the step needs none.
    pub scratch: usize,
    pub scratch_len: usize,
    /// Multi-parent ops: for each parent, the position of each of its
    /// tangent rows inside this node's (union) active set.
    pub parent_pos: Vec<Vec<usize>>,
    /// Slice: indices into the *parent's* tangent rows that survive the
    /// compile-time zero-row compaction.
    pub keep: Vec<usize>,
}

impl NodePlan {
    /// Tangent width.
    pub fn t(&self) -> usize {
        self.active.len()
    }
}

/// Closed-form model numbers carried for reporting without execution.
#[derive(Debug, Clone, Copy)]
pub struct PlanAnalytics {
    /// Appendix B DOF multiplication model (per batch row).
    pub dof_muls_model: u64,
    /// Appendix B Hessian-method multiplication model (per batch row).
    pub hessian_muls_model: u64,
    /// Appendix D Hessian-method peak tangent scalars (per batch row).
    pub hessian_peak_scalars: u64,
}

/// A compiled, reusable execution program for one `(graph, operator)` pair.
pub struct OperatorProgram {
    steps: Vec<Step>,
    nodes: Vec<NodePlan>,
    out_id: usize,
    n: usize,
    rank: usize,
    slab_per_row: usize,
    cost_per_row: Cost,
    /// Exact per-row cost of each schedule step (fused activation included
    /// in its Linear step). Summed with `finalize_cost_per_row` this equals
    /// `cost_per_row` identically — the invariant the per-step profiler
    /// (`rust/tests/observability.rs`) rides on.
    step_costs_per_row: Vec<Cost>,
    /// Per-row cost of the output finalization (the lower-order `c·φ`
    /// term); zero when `lower_order_c` is off.
    finalize_cost_per_row: Cost,
    peak_per_row_scalars: u64,
    opts: PlanOptions,
    key: PlanKey,
    analytics: PlanAnalytics,
    counts: GraphCounts,
    /// Lazily attached program-scheduled Hessian plan (shared through the
    /// global Hessian-plan cache; only baseline-running callers pay it).
    hessian_plan: OnceLock<Arc<hessian::HessianPlan>>,
}

impl OperatorProgram {
    /// Compile a program. Cost is O(nodes + weight scalars); no floating
    /// arithmetic on batch data happens here.
    pub fn compile(graph: &Graph, ldl: &LdlDecomposition, opts: PlanOptions) -> Self {
        let n = graph.input_dim();
        assert_eq!(ldl.n, n, "decomposition N != graph input dim");
        let r = ldl.rank();
        let len = graph.len();
        assert!(len > 0, "cannot compile an empty graph");
        let out_id = graph.output();

        // ---- liveness (eq. 24) ------------------------------------------
        let tau = graph.tau();
        let mut frees_at: Vec<Vec<usize>> = vec![Vec::new(); len];
        for i in 0..len {
            frees_at[tau[i]].push(i);
        }

        // ---- §3.2 active rows via structural support propagation --------
        let (actives, keeps, parent_poss) = propagate_support(graph, ldl, r, opts.sparsity);

        // ---- schedule with Linear→Activation fusion ---------------------
        let mut steps = build_schedule(graph, &tau);

        // ---- plan-time micro-kernel selection ---------------------------
        // Specialize each Linear step's GEMM from its batch-invariant
        // per-item shape: the stacked operand carries `t + 2` rows per
        // batch row (value + scalar + t tangent rows), with `t` read off
        // the §3.2 active sets just computed.
        for step in steps.iter_mut() {
            if let StepKind::Linear { gemm, .. } = &mut step.kind {
                if let Op::Linear { weight, .. } = &graph.node(step.node).op {
                    let (out_d, in_d) = (weight.dims()[0], weight.dims()[1]);
                    let t = actives[step.node].len();
                    *gemm = GemmPlan::choose(t + 2, in_d, out_d);
                }
            }
        }

        // ---- static slot assignment (per-row units) ---------------------
        let mut nodes: Vec<NodePlan> = (0..len)
            .map(|i| NodePlan {
                dim: graph.node(i).dim,
                active: actives[i].clone(),
                slot: 0,
                scratch: 0,
                scratch_len: 0,
                parent_pos: parent_poss[i].clone(),
                keep: keeps[i].clone(),
            })
            .collect();
        let mut lay = SlabLayout::new();
        let node_size = |np: &NodePlan| (np.t() + 2) * np.dim;
        for step in &steps {
            let id = step.node;
            let t = nodes[id].t();
            let dim = nodes[id].dim;
            nodes[id].slot = lay.alloc(node_size(&nodes[id]));
            // Step scratch, freed at end of step.
            let scratch_len = match &step.kind {
                StepKind::Linear { .. } => {
                    let in_d = graph.node(graph.node(id).inputs[0]).dim;
                    (t + 2) * in_d + (t + 2) * dim
                }
                StepKind::Mul => graph.node(id).inputs.len() * t * dim,
                _ => 0,
            };
            if scratch_len > 0 {
                nodes[id].scratch = lay.alloc(scratch_len);
                nodes[id].scratch_len = scratch_len;
            }
            lay.free(nodes[id].scratch, nodes[id].scratch_len);
            for &i in &frees_at[id] {
                if i != out_id {
                    lay.free(nodes[i].slot, node_size(&nodes[i]));
                }
            }
            if let StepKind::Linear {
                fused_act: Some(a), ..
            } = &step.kind
            {
                let a = *a;
                nodes[a].slot = lay.alloc(node_size(&nodes[a]));
                for &i in &frees_at[a] {
                    if i != out_id {
                        lay.free(nodes[i].slot, node_size(&nodes[i]));
                    }
                }
            }
        }
        let slab_per_row = lay.high_water();

        // ---- exact per-row cost & liveness peak (both linear in batch) --
        // Per-step costs are summed into the program total, so the two can
        // never disagree (the profiler's measured-vs-analytic table keys on
        // this).
        let step_costs_per_row: Vec<Cost> = steps
            .iter()
            .map(|step| {
                let mut c = node_cost_per_row(graph, &nodes, step.node);
                if let StepKind::Linear {
                    fused_act: Some(a), ..
                } = &step.kind
                {
                    let ac = node_cost_per_row(graph, &nodes, *a);
                    c.muls += ac.muls;
                    c.adds += ac.adds;
                }
                c
            })
            .collect();
        let finalize_cost_per_row = if opts.lower_order_c {
            Cost {
                muls: nodes[out_id].dim as u64,
                adds: 0,
            }
        } else {
            Cost::zero()
        };
        let mut cost_per_row = finalize_cost_per_row;
        for c in &step_costs_per_row {
            cost_per_row.muls += c.muls;
            cost_per_row.adds += c.adds;
        }
        let peak_per_row_scalars = peak_per_row(graph, &nodes, &frees_at, out_id);

        // ---- closed-form models (Appendix B/D) --------------------------
        let counts = graph_counts(graph);
        let model = CostModel {
            counts,
            n: n as u64,
            r: r as u64,
        };
        let hessian_peak_scalars = {
            // Appendix D: all width-N forward tangents live at once, plus
            // the widest reverse buffer (mirrors MemoryModel).
            let v = graph.scalar_node_count() as u64;
            let max_dim = graph.nodes().iter().map(|nd| nd.dim).max().unwrap_or(0) as u64;
            (n as u64) * v + (n as u64) * max_dim
        };
        let analytics = PlanAnalytics {
            dof_muls_model: model.dof_muls(),
            hessian_muls_model: model.hessian_muls(),
            hessian_peak_scalars,
        };

        let key = plan_key(graph, ldl, opts);
        OperatorProgram {
            steps,
            nodes,
            out_id,
            n,
            rank: r,
            slab_per_row,
            cost_per_row,
            step_costs_per_row,
            finalize_cost_per_row,
            peak_per_row_scalars,
            opts,
            key,
            analytics,
            counts,
            hessian_plan: OnceLock::new(),
        }
    }

    // ---- accessors -------------------------------------------------------

    pub fn steps(&self) -> &[Step] {
        &self.steps
    }

    pub fn node_plan(&self, id: usize) -> &NodePlan {
        &self.nodes[id]
    }

    pub fn node_count(&self) -> usize {
        self.nodes.len()
    }

    pub fn output(&self) -> usize {
        self.out_id
    }

    pub fn input_dim(&self) -> usize {
        self.n
    }

    /// DOF tangent width `r = rank(A)`.
    pub fn rank(&self) -> usize {
        self.rank
    }

    pub fn options(&self) -> PlanOptions {
        self.opts
    }

    pub fn key(&self) -> PlanKey {
        self.key
    }

    /// Number of fused `Linear→Activation` steps in the schedule.
    pub fn fused_steps(&self) -> usize {
        self.steps
            .iter()
            .filter(|s| matches!(s.kind, StepKind::Linear { fused_act: Some(_), .. }))
            .count()
    }

    /// Per-row slab scalars; one shard's slab is `slab_per_row · rows`.
    pub fn slab_per_row(&self) -> usize {
        self.slab_per_row
    }

    /// Slab length (f64 scalars) for a `batch`-row execution.
    pub fn slab_len(&self, batch: usize) -> usize {
        self.slab_per_row * batch
    }

    /// Exact FLOP count of executing `batch` rows — identical to what the
    /// reference interpreter accumulates at runtime (every term of the
    /// eq. 7–9 pass is linear in the batch).
    pub fn cost(&self, batch: usize) -> Cost {
        Cost {
            muls: self.cost_per_row.muls * batch as u64,
            adds: self.cost_per_row.adds * batch as u64,
        }
    }

    /// Exact FLOP count of schedule step `idx` at `batch` rows (a fused
    /// activation is charged to its Linear step, matching execution). The
    /// step costs plus [`Self::finalize_cost`] sum to [`Self::cost`]
    /// identically.
    pub fn step_cost(&self, idx: usize, batch: usize) -> Cost {
        let c = self.step_costs_per_row[idx];
        Cost {
            muls: c.muls * batch as u64,
            adds: c.adds * batch as u64,
        }
    }

    /// Exact FLOP count of the output finalization (the lower-order `c·φ`
    /// term) at `batch` rows; zero when `lower_order_c` is off.
    pub fn finalize_cost(&self, batch: usize) -> Cost {
        Cost {
            muls: self.finalize_cost_per_row.muls * batch as u64,
            adds: self.finalize_cost_per_row.adds * batch as u64,
        }
    }

    /// Exact peak live tangent bytes of a `batch`-row execution — the
    /// Theorem 2.2 `M₁` measurement, replayed from the same alloc/free
    /// event order the interpreter's [`crate::autodiff::PeakTracker`] sees.
    pub fn peak_tangent_bytes(&self, batch: usize) -> u64 {
        self.peak_per_row_scalars * 8 * batch as u64
    }

    /// Closed-form Appendix B/D model numbers.
    pub fn analytics(&self) -> PlanAnalytics {
        self.analytics
    }

    /// Scalar-level structural counts (`|E|`, `|R|`, `|T|`, `|V|`).
    pub fn graph_counts(&self) -> GraphCounts {
        self.counts
    }

    /// Active rows of the output node (global `L`-row indices).
    pub fn out_active(&self) -> &[usize] {
        &self.nodes[self.out_id].active
    }

    /// The program-scheduled [`hessian::HessianPlan`] for this program's
    /// graph, fetched from the global Hessian-plan cache on first use and
    /// pinned for the program's lifetime — so callers that compiled the DOF
    /// program once get the baseline on the same compiled machinery.
    ///
    /// The pinned plan is only served when its structural fingerprint
    /// matches `graph` (a value-move variant of the first graph); a caller
    /// handing a structurally different graph of the same shape gets the
    /// right plan from the global cache instead of the pinned one.
    pub fn hessian_plan(&self, graph: &Graph) -> Arc<hessian::HessianPlan> {
        assert_eq!(graph.len(), self.node_count(), "program/graph mismatch");
        assert_eq!(graph.input_dim(), self.n, "program/graph mismatch");
        let pinned = self
            .hessian_plan
            .get_or_init(|| hessian::global_hessian_cache().get_or_compile(graph));
        if pinned.key() == hessian::hessian_key(graph) {
            Arc::clone(pinned)
        } else {
            hessian::global_hessian_cache().get_or_compile(graph)
        }
    }
}

/// Build the step schedule for `graph`: the topological node walk with
/// `Linear → Activation` pairs fused into single steps. Shared by
/// [`OperatorProgram::compile`] and the jet compiler
/// ([`crate::jet::JetProgram`]) so both subsystems dispatch the same fused
/// MLP hot path.
pub(crate) fn build_schedule(graph: &Graph, tau: &[usize]) -> Vec<Step> {
    let len = graph.len();
    let mut steps: Vec<Step> = Vec::with_capacity(len);
    let mut in_off = 0usize;
    let mut j = 0usize;
    while j < len {
        let node = graph.node(j);
        let kind = match &node.op {
            Op::Input { dim } => {
                let k = StepKind::Input { in_off };
                in_off += *dim;
                k
            }
            Op::Linear { .. } => {
                // Fuse iff the linear's only consumer is the next node
                // and that node is an activation (consumer ids are > j,
                // so τ(j) == j+1 pins the consumer set to {j+1}).
                let fusable = j + 1 < len
                    && tau[j] == j + 1
                    && matches!(graph.node(j + 1).op, Op::Activation { .. })
                    && graph.node(j + 1).inputs == [j];
                StepKind::Linear {
                    fused_act: if fusable { Some(j + 1) } else { None },
                    // Neutral pre-specialization default; each compiler
                    // (operator / jet / Hessian) overwrites it with its own
                    // per-item row count before the schedule is executed.
                    gemm: GemmPlan::default(),
                }
            }
            Op::Activation { .. } => StepKind::Activation,
            Op::Slice { .. } => StepKind::Slice,
            Op::Add => StepKind::Add,
            Op::Mul => StepKind::Mul,
            Op::SumReduce => StepKind::SumReduce,
            Op::Concat => StepKind::Concat,
        };
        let fused = matches!(kind, StepKind::Linear { fused_act: Some(_), .. });
        steps.push(Step { node: j, kind });
        j += if fused { 2 } else { 1 };
    }
    steps
}

/// Per-node packed weight panels for one top-level execution, indexed by
/// graph node id (`None` for non-Linear nodes and Dot-form steps).
pub type PanelSet = Vec<Option<PackedPanel>>;

/// Pack the `Bᵀ` weight panels for every `PackedAxpy`-form Linear step of a
/// schedule.
///
/// Panels hold weight **values**, so they must never be stored in the
/// structure-keyed plan caches (which deliberately survive weight moves —
/// see [`cache::PlanCache`] and `rust/tests/cache_soundness.rs`). Engines
/// call this once per top-level execution and share the resulting set
/// read-only across shards; interpreters and the tape executor pass `None`
/// panels instead (the ad-hoc transpose is bit-identical to the packed
/// layout, see [`crate::tensor::PackedPanel`]).
pub fn pack_panels(steps: &[Step], graph: &Graph) -> PanelSet {
    let mut panels: PanelSet = (0..graph.len()).map(|_| None).collect();
    for step in steps {
        if let StepKind::Linear { gemm, .. } = &step.kind {
            if gemm.form == GemmForm::PackedAxpy {
                if let Op::Linear { weight, .. } = &graph.node(step.node).op {
                    let (out_d, in_d) = (weight.dims()[0], weight.dims()[1]);
                    panels[step.node] = Some(PackedPanel::pack(weight.data(), in_d, out_d));
                }
            }
        }
    }
    panels
}

/// Exact per-row FLOP cost of one node's eq. 7–9 propagation, mirroring
/// the reference interpreter's counting term by term (see
/// `DofEngine::compute_with_arena`). The program total is the sum of these
/// over all nodes plus the output finalization — there is exactly one cost
/// model, summed at different granularities.
pub(crate) fn node_cost_per_row(graph: &Graph, nodes: &[NodePlan], j: usize) -> Cost {
    let node = graph.node(j);
    let d = nodes[j].dim;
    let t = nodes[j].t();
    let mut c = Cost::zero();
    match &node.op {
        Op::Input { .. } | Op::Slice { .. } | Op::Concat => {}
        Op::Linear { weight, .. } => {
            let (out_d, in_d) = (weight.dims()[0], weight.dims()[1]);
            c.muls += ((t + 2) * out_d * in_d) as u64;
            c.adds += (t * out_d * in_d) as u64;
        }
        Op::Activation { .. } => {
            c.muls += (2 * t * d + 2 * d) as u64;
            c.adds += (t * d + d) as u64;
        }
        Op::Add => {
            let extra = node.inputs.len().saturating_sub(1);
            c.adds += (extra * (t * d + 2 * d)) as u64;
        }
        Op::Mul => {
            let k = node.inputs.len();
            // Value chain (outside the per-row loop in the interpreter,
            // but batch-linear all the same).
            c.muls += ((k - 1) * d) as u64;
            // Per parent: leave-one-out coefficient, tangent scale,
            // scalar-stream scale.
            c.muls += (k * ((k - 1) * d + t * d + d)) as u64;
            // Per unordered pair: cross contraction + 2× scale.
            let pairs = k * (k - 1) / 2;
            c.muls += (pairs * (t * d + 2 * d)) as u64;
        }
        Op::SumReduce => {
            let p = node.inputs[0];
            let pd = nodes[p].dim;
            let pt = nodes[p].t();
            c.adds += (pt * pd + 2 * pd) as u64;
        }
    }
    c
}

/// Replay the interpreter's tangent alloc/free event order analytically:
/// at node `j` allocate `t_j·d_j`, then free every `i` with `τ(i) = j`
/// except the output. Returns the peak in per-row scalars.
fn peak_per_row(
    graph: &Graph,
    nodes: &[NodePlan],
    frees_at: &[Vec<usize>],
    out_id: usize,
) -> u64 {
    let mut live = 0u64;
    let mut peak = 0u64;
    for j in 0..graph.len() {
        live += (nodes[j].t() * nodes[j].dim) as u64;
        if live > peak {
            peak = live;
        }
        for &i in &frees_at[j] {
            if i != out_id {
                live -= (nodes[i].t() * nodes[i].dim) as u64;
            }
        }
    }
    peak
}

// ---- structural support propagation (§3.2) ------------------------------

fn words(bits: usize) -> usize {
    (bits + 63) / 64
}

fn bit_get(mask: &[u64], i: usize) -> bool {
    mask[i / 64] & (1u64 << (i % 64)) != 0
}

fn bit_set(mask: &mut [u64], i: usize) {
    mask[i / 64] |= 1u64 << (i % 64);
}

fn any_bit(mask: &[u64]) -> bool {
    mask.iter().any(|&w| w != 0)
}

fn or_into(dst: &mut [u64], src: &[u64]) {
    for (d, &s) in dst.iter_mut().zip(src) {
        *d |= s;
    }
}

/// Compute per-node active tangent rows, slice keep-maps, and multi-parent
/// union position maps.
///
/// With sparsity on, a per-(row, component) *support* bitmask (could this
/// entry be nonzero for some input?) is pushed through the graph; the
/// active-set rules mirror the interpreter exactly: rows are pruned only
/// where the interpreter prunes them — at input nodes (scanning `L`'s
/// columns) and at slice nodes (zero-row compaction, which for slices of
/// input nodes is purely structural: the sliced tangent rows *are* rows of
/// `L`). Everywhere else the active set is inherited (chain ops) or
/// union-merged (multi-parent ops), pruned or not.
#[allow(clippy::type_complexity)]
fn propagate_support(
    graph: &Graph,
    ldl: &LdlDecomposition,
    r: usize,
    sparsity: bool,
) -> (Vec<Vec<usize>>, Vec<Vec<usize>>, Vec<Vec<Vec<usize>>>) {
    let len = graph.len();
    let mut actives: Vec<Vec<usize>> = vec![Vec::new(); len];
    let mut keeps: Vec<Vec<usize>> = vec![Vec::new(); len];
    let mut parent_poss: Vec<Vec<Vec<usize>>> = vec![Vec::new(); len];

    if !sparsity {
        // Dense mode: full-width tangents everywhere; identity maps.
        let full: Vec<usize> = (0..r).collect();
        for (j, node) in graph.nodes().iter().enumerate() {
            actives[j] = full.clone();
            match &node.op {
                Op::Slice { .. } => keeps[j] = full.clone(),
                Op::Add | Op::Mul | Op::Concat => {
                    parent_poss[j] = node.inputs.iter().map(|_| full.clone()).collect();
                }
                _ => {}
            }
        }
        return (actives, keeps, parent_poss);
    }

    // masks[j]: per active row, a bitmask over the node's components.
    let mut masks: Vec<Vec<Vec<u64>>> = vec![Vec::new(); len];
    let mut in_off = 0usize;

    for j in 0..len {
        let node = graph.node(j);
        let d = node.dim;
        match &node.op {
            Op::Input { dim } => {
                let mut active = Vec::new();
                let mut rows = Vec::new();
                for k in 0..r {
                    let lrow = &ldl.l.row(k)[in_off..in_off + dim];
                    if lrow.iter().any(|&v| v != 0.0) {
                        let mut m = vec![0u64; words(d)];
                        for (c, &v) in lrow.iter().enumerate() {
                            if v != 0.0 {
                                bit_set(&mut m, c);
                            }
                        }
                        active.push(k);
                        rows.push(m);
                    }
                }
                in_off += dim;
                actives[j] = active;
                masks[j] = rows;
            }
            Op::Linear { weight, .. } => {
                let p = node.inputs[0];
                let (out_d, in_d) = (weight.dims()[0], weight.dims()[1]);
                // Column support of W: which outputs each input touches.
                let w = weight.data();
                let ow = words(out_d);
                let mut cols: Vec<Vec<u64>> = vec![vec![0u64; ow]; in_d];
                for o in 0..out_d {
                    for (c, col) in cols.iter_mut().enumerate() {
                        if w[o * in_d + c] != 0.0 {
                            bit_set(col, o);
                        }
                    }
                }
                actives[j] = actives[p].clone();
                masks[j] = masks[p]
                    .iter()
                    .map(|prow| {
                        let mut out = vec![0u64; ow];
                        for c in 0..in_d {
                            if bit_get(prow, c) {
                                or_into(&mut out, &cols[c]);
                            }
                        }
                        out
                    })
                    .collect();
            }
            Op::Activation { .. } => {
                let p = node.inputs[0];
                actives[j] = actives[p].clone();
                masks[j] = masks[p].clone();
            }
            Op::Slice { start, len: slen } => {
                let p = node.inputs[0];
                let mut keep = Vec::new();
                let mut active = Vec::new();
                let mut rows = Vec::new();
                for (kk, prow) in masks[p].iter().enumerate() {
                    let mut m = vec![0u64; words(*slen)];
                    for i in 0..*slen {
                        if bit_get(prow, start + i) {
                            bit_set(&mut m, i);
                        }
                    }
                    if any_bit(&m) {
                        keep.push(kk);
                        active.push(actives[p][kk]);
                        rows.push(m);
                    }
                }
                keeps[j] = keep;
                actives[j] = active;
                masks[j] = rows;
            }
            Op::Add | Op::Mul | Op::Concat => {
                let mut union: Vec<usize> = Vec::new();
                for &p in &node.inputs {
                    union.extend_from_slice(&actives[p]);
                }
                union.sort_unstable();
                union.dedup();
                let pos: Vec<Vec<usize>> = node
                    .inputs
                    .iter()
                    .map(|&p| {
                        actives[p]
                            .iter()
                            .map(|k| union.binary_search(k).expect("active ⊆ union"))
                            .collect()
                    })
                    .collect();
                let wdim = words(d);
                let mut rows: Vec<Vec<u64>> = vec![vec![0u64; wdim]; union.len()];
                match &node.op {
                    Op::Concat => {
                        let mut off = 0usize;
                        for (pi, &p) in node.inputs.iter().enumerate() {
                            let pd = graph.node(p).dim;
                            for (kk, prow) in masks[p].iter().enumerate() {
                                let u = pos[pi][kk];
                                for i in 0..pd {
                                    if bit_get(prow, i) {
                                        bit_set(&mut rows[u], off + i);
                                    }
                                }
                            }
                            off += pd;
                        }
                    }
                    _ => {
                        // Add / Mul: component-aligned union of supports.
                        for (pi, &p) in node.inputs.iter().enumerate() {
                            for (kk, prow) in masks[p].iter().enumerate() {
                                or_into(&mut rows[pos[pi][kk]], prow);
                            }
                        }
                    }
                }
                actives[j] = union;
                parent_poss[j] = pos;
                masks[j] = rows;
            }
            Op::SumReduce => {
                let p = node.inputs[0];
                actives[j] = actives[p].clone();
                masks[j] = masks[p]
                    .iter()
                    .map(|prow| {
                        let mut m = vec![0u64; 1];
                        if any_bit(prow) {
                            bit_set(&mut m, 0);
                        }
                        m
                    })
                    .collect();
            }
        }
    }
    (actives, keeps, parent_poss)
}

// ---- fingerprinting ------------------------------------------------------

/// FNV-1a 64-bit accumulator (shared with the jet subsystem's key).
pub(crate) struct Fnv(pub(crate) u64);

impl Fnv {
    pub(crate) fn new() -> Self {
        Fnv(0xcbf29ce484222325)
    }

    pub(crate) fn u64(&mut self, v: u64) {
        for b in v.to_le_bytes() {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x100000001b3);
        }
    }

    pub(crate) fn bits(&mut self, it: impl Iterator<Item = bool>) {
        let mut word = 0u64;
        let mut nb = 0u32;
        for b in it {
            word = (word << 1) | b as u64;
            nb += 1;
            if nb == 64 {
                self.u64(word);
                word = 0;
                nb = 0;
            }
        }
        if nb > 0 {
            self.u64(word);
            self.u64(nb as u64);
        }
    }
}

fn act_tag(act: Act) -> u64 {
    match act {
        Act::Tanh => 1,
        Act::Sin => 2,
        Act::Gelu => 3,
        Act::Softplus => 4,
        Act::Square => 5,
        Act::Identity => 6,
    }
}

/// Hash the value-independent *structure* of a graph into `h`: op kinds,
/// dims, wiring, activation tags, and weight zero patterns — never weight
/// values. Shared by [`plan_key`] and the jet subsystem's program key.
pub(crate) fn hash_graph_structure(h: &mut Fnv, graph: &Graph) {
    h.u64(graph.len() as u64);
    for node in graph.nodes() {
        h.u64(node.dim as u64);
        h.u64(node.inputs.len() as u64);
        for &p in &node.inputs {
            h.u64(p as u64);
        }
        match &node.op {
            Op::Input { dim } => {
                h.u64(10);
                h.u64(*dim as u64);
            }
            Op::Linear { weight, bias } => {
                h.u64(11);
                h.u64(weight.dims()[0] as u64);
                h.u64(weight.dims()[1] as u64);
                h.u64(bias.len() as u64);
                h.bits(weight.data().iter().map(|&v| v != 0.0));
            }
            Op::Activation { act } => {
                h.u64(12);
                h.u64(act_tag(*act));
            }
            Op::Slice { start, len } => {
                h.u64(13);
                h.u64(*start as u64);
                h.u64(*len as u64);
            }
            Op::Add => h.u64(14),
            Op::Mul => h.u64(15),
            Op::SumReduce => h.u64(16),
            Op::Concat => h.u64(17),
        }
    }
}

/// Value-independent structural fingerprint of `(graph, ldl, opts)` — the
/// cache key under which a compiled program is valid.
pub fn plan_key(graph: &Graph, ldl: &LdlDecomposition, opts: PlanOptions) -> PlanKey {
    let mut h = Fnv::new();
    hash_graph_structure(&mut h, graph);
    h.u64(ldl.n as u64);
    h.u64(ldl.rank() as u64);
    h.bits(ldl.l.data().iter().map(|&v| v != 0.0));
    h.bits(ldl.d.iter().map(|&s| s >= 0.0));
    h.u64(opts.sparsity as u64);
    h.u64(opts.lower_order_c as u64);
    PlanKey {
        fingerprint: h.0,
        nodes: graph.len(),
        n: graph.input_dim(),
        rank: ldl.rank(),
        sparsity: opts.sparsity,
        lower_order_c: opts.lower_order_c,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;
    use crate::graph::{builder::random_layers, mlp_graph, sparse_mlp_graph};
    use crate::operators::CoeffSpec;
    use crate::util::Xoshiro256;

    fn random_symmetric(n: usize, rng: &mut Xoshiro256) -> Tensor {
        let b = Tensor::randn(&[n, n], rng);
        b.add(&b.transpose()).scale(0.5)
    }

    #[test]
    fn mlp_schedule_is_fully_fused() {
        let mut rng = Xoshiro256::new(1);
        let g = mlp_graph(&random_layers(&[6, 12, 12, 1], &mut rng), Act::Tanh);
        let ldl = LdlDecomposition::of(&random_symmetric(6, &mut rng));
        let p = OperatorProgram::compile(
            &g,
            &ldl,
            PlanOptions {
                sparsity: true,
                lower_order_c: false,
            },
        );
        // input + 2 fused (lin,act) + final linear = 4 steps over 6 nodes.
        assert_eq!(p.steps().len(), 4);
        assert_eq!(p.fused_steps(), 2);
        assert_eq!(p.rank(), 6);
        assert!(p.slab_per_row() > 0);
    }

    #[test]
    fn block_diag_operator_prunes_rows_per_block() {
        let mut rng = Xoshiro256::new(2);
        let blocks: Vec<_> = (0..4)
            .map(|_| random_layers(&[3, 8, 4], &mut rng))
            .collect();
        let g = sparse_mlp_graph(&blocks, Act::Tanh);
        let a = CoeffSpec::BlockDiagGram {
            blocks: 4,
            block: 3,
            rank: 3,
            seed: 5,
        }
        .build();
        let ldl = LdlDecomposition::of(&a);
        let r = ldl.rank();
        let sparse = OperatorProgram::compile(
            &g,
            &ldl,
            PlanOptions {
                sparsity: true,
                lower_order_c: false,
            },
        );
        let dense = OperatorProgram::compile(
            &g,
            &ldl,
            PlanOptions {
                sparsity: false,
                lower_order_c: false,
            },
        );
        // Per-block slices must carry ~r/4 rows, not all r.
        let mut pruned = false;
        for (id, node) in g.nodes().iter().enumerate() {
            if matches!(node.op, Op::Slice { .. }) {
                assert!(sparse.node_plan(id).t() < r);
                assert_eq!(dense.node_plan(id).t(), r);
                pruned = true;
            }
        }
        assert!(pruned, "sparse architecture should have slice nodes");
        assert!(sparse.cost(1).muls < dense.cost(1).muls);
        assert!(sparse.peak_tangent_bytes(1) < dense.peak_tangent_bytes(1));
    }

    #[test]
    fn cost_and_peak_scale_exactly_with_batch() {
        let mut rng = Xoshiro256::new(3);
        let g = mlp_graph(&random_layers(&[4, 9, 1], &mut rng), Act::Sin);
        let ldl = LdlDecomposition::of(&random_symmetric(4, &mut rng));
        let p = OperatorProgram::compile(
            &g,
            &ldl,
            PlanOptions {
                sparsity: true,
                lower_order_c: true,
            },
        );
        let c1 = p.cost(1);
        let c7 = p.cost(7);
        assert_eq!(c7.muls, 7 * c1.muls);
        assert_eq!(c7.adds, 7 * c1.adds);
        assert_eq!(p.peak_tangent_bytes(7), 7 * p.peak_tangent_bytes(1));
        assert_eq!(p.slab_len(7), 7 * p.slab_per_row());
    }

    #[test]
    fn step_costs_sum_to_program_cost() {
        let mut rng = Xoshiro256::new(9);
        let g = mlp_graph(&random_layers(&[5, 11, 7, 1], &mut rng), Act::Gelu);
        let ldl = LdlDecomposition::of(&random_symmetric(5, &mut rng));
        for lower in [false, true] {
            let p = OperatorProgram::compile(
                &g,
                &ldl,
                PlanOptions {
                    sparsity: true,
                    lower_order_c: lower,
                },
            );
            for batch in [1usize, 3, 16] {
                let mut sum = p.finalize_cost(batch);
                for i in 0..p.steps().len() {
                    let c = p.step_cost(i, batch);
                    sum.muls += c.muls;
                    sum.adds += c.adds;
                }
                assert_eq!(sum.muls, p.cost(batch).muls);
                assert_eq!(sum.adds, p.cost(batch).adds);
            }
        }
    }

    #[test]
    fn key_ignores_weight_values_but_not_structure() {
        let mut rng = Xoshiro256::new(4);
        let layers = random_layers(&[3, 5, 1], &mut rng);
        let g1 = mlp_graph(&layers, Act::Tanh);
        // Same topology, different (still dense) values.
        let layers2 = random_layers(&[3, 5, 1], &mut rng);
        let g2 = mlp_graph(&layers2, Act::Tanh);
        let g3 = mlp_graph(&random_layers(&[3, 6, 1], &mut rng), Act::Tanh);
        let ldl = LdlDecomposition::of(&random_symmetric(3, &mut rng));
        let opts = PlanOptions {
            sparsity: true,
            lower_order_c: false,
        };
        assert_eq!(plan_key(&g1, &ldl, opts), plan_key(&g2, &ldl, opts));
        assert_ne!(plan_key(&g1, &ldl, opts), plan_key(&g3, &ldl, opts));
        let opts2 = PlanOptions {
            sparsity: false,
            lower_order_c: false,
        };
        assert_ne!(plan_key(&g1, &ldl, opts), plan_key(&g1, &ldl, opts2));
    }

    #[test]
    fn linear_steps_record_shape_driven_gemm_plans() {
        let mut rng = Xoshiro256::new(6);
        let g = mlp_graph(&random_layers(&[8, 32, 32, 1], &mut rng), Act::Tanh);
        let ldl = LdlDecomposition::of(&random_symmetric(8, &mut rng));
        let p = OperatorProgram::compile(
            &g,
            &ldl,
            PlanOptions {
                sparsity: true,
                lower_order_c: false,
            },
        );
        let (mut saw_dot, mut saw_axpy) = (false, false);
        for step in p.steps() {
            if let StepKind::Linear { gemm, .. } = &step.kind {
                let Op::Linear { weight, .. } = &g.node(step.node).op else {
                    panic!("Linear step on non-Linear node");
                };
                let (out_d, in_d) = (weight.dims()[0], weight.dims()[1]);
                let t = p.node_plan(step.node).t();
                assert_eq!(*gemm, GemmPlan::choose(t + 2, in_d, out_d));
                match gemm.form {
                    GemmForm::Dot => saw_dot = true,
                    GemmForm::PackedAxpy => saw_axpy = true,
                }
            }
        }
        assert!(
            saw_dot && saw_axpy,
            "[8,32,32,1] should select both GEMM forms"
        );
        // Panels are packed exactly for the PackedAxpy-form steps.
        let panels = pack_panels(p.steps(), &g);
        for step in p.steps() {
            if let StepKind::Linear { gemm, .. } = &step.kind {
                assert_eq!(
                    panels[step.node].is_some(),
                    gemm.form == GemmForm::PackedAxpy
                );
            }
        }
    }

    #[test]
    fn analytics_match_cost_model() {
        let mut rng = Xoshiro256::new(5);
        let g = mlp_graph(&random_layers(&[8, 16, 16, 1], &mut rng), Act::Tanh);
        let ldl = LdlDecomposition::of(&random_symmetric(8, &mut rng));
        let p = OperatorProgram::compile(
            &g,
            &ldl,
            PlanOptions {
                sparsity: true,
                lower_order_c: false,
            },
        );
        let m = CostModel::new(&g, p.rank());
        assert_eq!(p.analytics().dof_muls_model, m.dof_muls());
        assert_eq!(p.analytics().hessian_muls_model, m.hessian_muls());
        assert!(p.analytics().hessian_peak_scalars > 0);
    }
}
