//! Seeded random `(architecture, operator, batch)` cases for the
//! cross-engine differential harness (`rust/tests/cross_engine_fuzz.rs`).
//!
//! Each draw produces a scalar-output graph from one of four families —
//! plain MLP, the block-sparse product head (`Slice`/`Mul`/`SumReduce`),
//! two added branches (`Add`), and a concat head (`Slice`/`Concat`) — with
//! random depths, widths, activation mix, and (sometimes) sparsified
//! weight zero patterns, plus a random constant-coefficient operator
//! `Σ a_ij ∂²_ij + Σ b_i ∂_i + c`: dense symmetric, low-rank PSD
//! (rank-deficient `L`), block-diagonal Gram, or signed (possibly
//! rank-deficient) diagonal. Inputs are kept small (`N ≤ 6`) so a central
//! finite difference of the graph's forward evaluation is a practical
//! independent oracle for every case.
//!
//! Everything is a pure function of the [`Gen`] seed, so a failing case
//! reproduces from the seed [`super::run_prop`] prints.

use crate::graph::builder::LayerWeights;
use crate::graph::{builder::append_mlp, mlp_graph, sparse_mlp_graph, Act, Graph};
use crate::tensor::{matmul, Tensor};

use super::Gen;

/// One random differential-testing case.
pub struct OperatorCase {
    pub graph: Graph,
    /// Symmetric coefficient matrix `A` (never all-zero).
    pub a: Tensor,
    /// Optional first-order coefficients.
    pub b: Option<Vec<f64>>,
    /// Optional zeroth-order coefficient.
    pub c: Option<f64>,
    /// Evaluation batch `[batch, N]`.
    pub x: Tensor,
    /// Architecture family tag (diagnostics).
    pub family: &'static str,
}

impl OperatorCase {
    pub fn n(&self) -> usize {
        self.graph.input_dim()
    }

    pub fn batch(&self) -> usize {
        self.x.dims()[0]
    }
}

fn random_act(g: &mut Gen) -> Act {
    g.choice(&[Act::Tanh, Act::Sin, Act::Softplus, Act::Gelu])
}

/// Random layer stack, sometimes with a sparsified weight zero pattern.
fn layers(g: &mut Gen, dims: &[usize]) -> LayerWeights {
    let mut ls = crate::graph::builder::random_layers(dims, g.rng());
    // Sometimes sparsify weight zero patterns (exercises the structural
    // support propagation and the value-independent cache keys).
    if g.bool_with(0.4) {
        for (w, _) in ls.iter_mut() {
            let numel = w.numel();
            // Zero ~30% of entries, but never a whole row (keeps every
            // neuron — and therefore the whole graph — output-connected).
            let cols = w.dims()[1];
            if cols < 2 {
                continue;
            }
            for i in 0..numel {
                if g.bool_with(0.3) {
                    let (r, c) = (i / cols, i % cols);
                    // Keep column 0 of every row as an anchor.
                    if c != 0 {
                        w.data_mut()[r * cols + c] = 0.0;
                    }
                }
            }
        }
    }
    ls
}

/// Random scalar-output architecture on `n` inputs.
fn random_graph(g: &mut Gen, n: usize) -> (Graph, &'static str) {
    match g.usize_in(0, 3) {
        0 => {
            // Plain MLP.
            let depth = g.usize_in(1, 3);
            let mut dims = vec![n];
            for _ in 0..depth {
                dims.push(g.usize_in(2, 10));
            }
            dims.push(1);
            let act = random_act(g);
            let ls = layers(g, &dims);
            (mlp_graph(&ls, act), "mlp")
        }
        1 => {
            // Block-sparse product head (Slice → per-block MLP → Mul →
            // SumReduce). Needs n = blocks · block_in with blocks ≥ 2.
            let blocks = if n % 2 == 0 { 2 } else { 3 };
            let block_in = n / blocks;
            debug_assert_eq!(blocks * block_in, n);
            let hidden = g.usize_in(2, 6);
            let out_dim = g.usize_in(1, 3);
            let act = random_act(g);
            let bls: Vec<LayerWeights> = (0..blocks)
                .map(|_| layers(g, &[block_in, hidden, out_dim]))
                .collect();
            (sparse_mlp_graph(&bls, act), "sparse-product")
        }
        2 => {
            // Two added branches over the same input (Add).
            let act1 = random_act(g);
            let act2 = random_act(g);
            let h1 = g.usize_in(2, 8);
            let h2 = g.usize_in(2, 8);
            let l1 = layers(g, &[n, h1, 1]);
            let l2 = layers(g, &[n, h2, 1]);
            let mut graph = Graph::new();
            let x = graph.input(n);
            let b1 = append_mlp(&mut graph, x, &l1, act1);
            let b2 = append_mlp(&mut graph, x, &l2, act2);
            graph.add(vec![b1, b2]);
            (graph, "add-branches")
        }
        _ => {
            // Concat head: slice the input in two, MLP each part, concat,
            // linear to a scalar.
            let n1 = g.usize_in(1, n - 1);
            let n2 = n - n1;
            let (d1, d2) = (g.usize_in(1, 3), g.usize_in(1, 3));
            let act = random_act(g);
            let l1 = layers(g, &[n1, g.usize_in(2, 6), d1]);
            let l2 = layers(g, &[n2, g.usize_in(2, 6), d2]);
            let head = layers(g, &[d1 + d2, 1]);
            let mut graph = Graph::new();
            let x = graph.input(n);
            let s1 = graph.slice(x, 0, n1);
            let s2 = graph.slice(x, n1, n2);
            let m1 = append_mlp(&mut graph, s1, &l1, act);
            let m2 = append_mlp(&mut graph, s2, &l2, act);
            let cat = graph.push(crate::graph::Op::Concat, vec![m1, m2]);
            append_mlp(&mut graph, cat, &head, act);
            (graph, "concat-head")
        }
    }
}

/// Random symmetric coefficient matrix — guaranteed nonzero, sometimes
/// rank-deficient (`rank(L) < N`), sometimes with a sparse zero pattern.
fn random_coeff(g: &mut Gen, n: usize) -> Tensor {
    match g.usize_in(0, 3) {
        0 => {
            // Full symmetric (possibly indefinite).
            let b = Tensor::randn(&[n, n], g.rng());
            b.add(&b.transpose()).scale(0.5)
        }
        1 => {
            // Low-rank PSD: rank-deficient L is the §2.2 low-rank path.
            let r = g.usize_in(1, n.max(2) - 1);
            let b = Tensor::randn(&[n, r], g.rng());
            matmul(&b, &b.transpose())
        }
        2 => {
            // Signed diagonal with random zeros (sparse, rank-deficient L
            // pattern; at least one entry kept nonzero).
            let mut a = Tensor::zeros(&[n, n]);
            let keep = g.usize_in(0, n - 1);
            for i in 0..n {
                let v = if g.bool_with(0.35) && i != keep {
                    0.0
                } else if g.bool_with(0.3) {
                    -1.0
                } else {
                    1.0
                };
                a.set(i, i, v);
            }
            a
        }
        _ => {
            // Block-diagonal Gram (two blocks), the Table 2 operator shape.
            let b1 = n / 2;
            let mut a = Tensor::zeros(&[n, n]);
            for (off, len) in [(0usize, b1), (b1, n - b1)] {
                if len == 0 {
                    continue;
                }
                let m = Tensor::randn(&[len, len], g.rng());
                let gram = matmul(&m, &m.transpose());
                for i in 0..len {
                    for j in 0..len {
                        a.set(off + i, off + j, gram.at(i, j));
                    }
                }
            }
            a
        }
    }
}

/// Draw one full differential-testing case.
pub fn random_operator_case(g: &mut Gen) -> OperatorCase {
    // N ∈ 2..=6 keeps the N² finite-difference oracle cheap; the sparse
    // family needs N divisible by its block count, so draw from shapes
    // that every family can use.
    let n = g.choice(&[2usize, 3, 4, 4, 6, 6]);
    let (graph, family) = random_graph(g, n);
    let a = random_coeff(g, n);
    let b = if g.bool_with(0.5) {
        Some((0..n).map(|_| g.normal()).collect())
    } else {
        None
    };
    let c = if g.bool_with(0.5) {
        Some(g.f64_in(-2.0, 2.0))
    } else {
        None
    };
    let batch = g.usize_in(1, 3);
    let scale = if family == "sparse-product" { 0.4 } else { 0.6 };
    let x = Tensor::randn(&[batch, n], g.rng()).scale(scale);
    OperatorCase {
        graph,
        a,
        b,
        c,
        x,
        family,
    }
}

/// A differential-testing case whose batch carries non-finite values
/// (NaN/±Inf) at seeded positions — the poisoned-input family for the
/// serving front door and engine validation gates. Every engine must
/// reject the batch with the **identical** message (they all delegate to
/// [`crate::tensor::ops::validate_batch_input`]) *before* any propagation
/// runs; `rust/tests/cross_engine_fuzz.rs` asserts exactly that.
pub struct PoisonedCase {
    pub case: OperatorCase,
    /// Poisoned positions `(row, col, value)` in draw order (later draws
    /// may overwrite earlier ones at the same position; `case.x` is the
    /// ground truth).
    pub poison: Vec<(usize, usize, f64)>,
}

/// Draw a well-formed case, then poison 1–3 seeded positions of its batch
/// with NaN / +Inf / −Inf.
pub fn poisoned_operator_case(g: &mut Gen) -> PoisonedCase {
    let mut case = random_operator_case(g);
    let (batch, n) = (case.batch(), case.n());
    let k = g.usize_in(1, 3.min(batch * n));
    let mut poison = Vec::with_capacity(k);
    for _ in 0..k {
        let r = g.usize_in(0, batch - 1);
        let c = g.usize_in(0, n - 1);
        let v = g.choice(&[f64::NAN, f64::INFINITY, f64::NEG_INFINITY]);
        case.x.set(r, c, v);
        poison.push((r, c, v));
    }
    PoisonedCase { case, poison }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop::run_prop;

    #[test]
    fn cases_are_well_formed_and_deterministic() {
        run_prop("generator well-formed", 60, 9090, |g| {
            let case = random_operator_case(g);
            let n = case.n();
            if case.a.dims() != [n, n] {
                return Err("A shape".into());
            }
            if case.a.data().iter().all(|&v| v == 0.0) {
                return Err("A must not be all-zero".into());
            }
            if case.graph.node(case.graph.output()).dim != 1 {
                return Err("output must be scalar".into());
            }
            let y = case.graph.eval(&case.x);
            if !y.all_finite() {
                return Err("forward eval must be finite".into());
            }
            Ok(())
        });
        // Determinism: same seed, same draw.
        let mut g1 = crate::prop::Gen::new(777);
        let mut g2 = crate::prop::Gen::new(777);
        let c1 = random_operator_case(&mut g1);
        let c2 = random_operator_case(&mut g2);
        assert_eq!(c1.family, c2.family);
        assert_eq!(c1.a, c2.a);
        assert_eq!(c1.x, c2.x);
    }

    #[test]
    fn poisoned_cases_are_rejected_by_the_shared_gate() {
        run_prop("poisoned generator", 40, 4242, |g| {
            let p = poisoned_operator_case(g);
            if p.poison.is_empty() {
                return Err("must poison at least one position".into());
            }
            if crate::tensor::ops::first_non_finite(p.case.x.data()).is_none() {
                return Err("x must carry a non-finite value".into());
            }
            match crate::tensor::ops::validate_batch_input(p.case.n(), &p.case.x) {
                Err(msg) if msg.contains("non-finite input at row") => Ok(()),
                Err(msg) => Err(format!("unexpected rejection message: {msg}")),
                Ok(()) => Err("validation must reject poisoned input".into()),
            }
        });
        // Determinism: same seed, same poison schedule.
        let mut g1 = crate::prop::Gen::new(31337);
        let mut g2 = crate::prop::Gen::new(31337);
        let p1 = poisoned_operator_case(&mut g1);
        let p2 = poisoned_operator_case(&mut g2);
        assert_eq!(p1.poison.len(), p2.poison.len());
        for (a, b) in p1.poison.iter().zip(&p2.poison) {
            assert_eq!((a.0, a.1), (b.0, b.1));
            assert!(a.2 == b.2 || (a.2.is_nan() && b.2.is_nan()));
        }
    }
}
