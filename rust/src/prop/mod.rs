//! Property-based testing substrate (no `proptest` in the offline build).
//!
//! A small, deterministic framework: a [`Gen`] wraps the repo PRNG with
//! convenience samplers; [`run_prop`] drives N seeded cases and reports the
//! first failing seed so failures are reproducible by pinning that seed;
//! [`generator`] draws random `(architecture, operator, batch)` cases for
//! the cross-engine differential harness.

pub mod generator;

use crate::util::Xoshiro256;

/// Generator context handed to property bodies.
pub struct Gen {
    rng: Xoshiro256,
    /// Seed of this case (for failure reporting).
    pub case_seed: u64,
}

impl Gen {
    pub fn new(seed: u64) -> Self {
        Self {
            rng: Xoshiro256::new(seed),
            case_seed: seed,
        }
    }

    pub fn rng(&mut self) -> &mut Xoshiro256 {
        &mut self.rng
    }

    /// usize in `[lo, hi]` inclusive.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi >= lo);
        lo + self.rng.below(hi - lo + 1)
    }

    /// f64 uniform in `[lo, hi)`.
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.uniform(lo, hi)
    }

    /// Standard normal.
    pub fn normal(&mut self) -> f64 {
        self.rng.normal()
    }

    /// Vector of standard normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.rng.normal()).collect()
    }

    /// Pick one of the listed values.
    pub fn choice<T: Copy>(&mut self, xs: &[T]) -> T {
        *self.rng.choose(xs)
    }

    /// Boolean with probability `p`.
    pub fn bool_with(&mut self, p: f64) -> bool {
        self.rng.bernoulli(p)
    }
}

/// Outcome of a property body: `Ok(())` passes, `Err(msg)` fails with a
/// diagnostic.
pub type PropResult = Result<(), String>;

/// Run `cases` seeded instances of the property. Panics (test failure) on
/// the first failing case, printing the case seed for reproduction.
pub fn run_prop(name: &str, cases: u64, base_seed: u64, mut body: impl FnMut(&mut Gen) -> PropResult) {
    for c in 0..cases {
        // Derive a well-separated per-case seed.
        let case_seed = base_seed
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add(c.wrapping_mul(0xD1B54A32D192ED03));
        let mut g = Gen::new(case_seed);
        if let Err(msg) = body(&mut g) {
            panic!(
                "property '{name}' failed at case {c}/{cases} (seed {case_seed:#x}): {msg}"
            );
        }
    }
}

/// Assert two floats are close (absolute or relative); returns a PropResult.
pub fn close(a: f64, b: f64, tol: f64) -> PropResult {
    let diff = (a - b).abs();
    let scale = a.abs().max(b.abs()).max(1.0);
    if diff <= tol * scale {
        Ok(())
    } else {
        Err(format!("|{a} - {b}| = {diff} > {tol}·{scale}"))
    }
}

/// Assert slices are elementwise close.
pub fn close_slice(a: &[f64], b: &[f64], tol: f64) -> PropResult {
    if a.len() != b.len() {
        return Err(format!("length {} vs {}", a.len(), b.len()));
    }
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        close(x, y, tol).map_err(|e| format!("index {i}: {e}"))?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        run_prop("sum-commutes", 50, 1, |g| {
            count += 1;
            let a = g.normal();
            let b = g.normal();
            close(a + b, b + a, 1e-15)
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property 'always-fails'")]
    fn failing_property_panics_with_seed() {
        run_prop("always-fails", 10, 2, |_g| Err("nope".into()));
    }

    #[test]
    fn deterministic_across_runs() {
        let mut first: Vec<f64> = Vec::new();
        run_prop("collect", 5, 3, |g| {
            first.push(g.normal());
            Ok(())
        });
        let mut second: Vec<f64> = Vec::new();
        run_prop("collect", 5, 3, |g| {
            second.push(g.normal());
            Ok(())
        });
        assert_eq!(first, second);
    }

    #[test]
    fn close_slice_reports_index() {
        let e = close_slice(&[1.0, 2.0], &[1.0, 3.0], 1e-9).unwrap_err();
        assert!(e.contains("index 1"));
    }
}
