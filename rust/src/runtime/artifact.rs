//! Artifact registry: discovery and lazy compilation of `artifacts/*.hlo.txt`.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

/// Parsed manifest entry for one artifact.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    /// File name, e.g. `dof_mlp_elliptic.hlo.txt`.
    pub file: String,
    /// Logical name (file stem before `.hlo.txt`).
    pub name: String,
    /// Free-form description from the manifest (shapes etc.).
    pub detail: String,
}

/// Registry over an artifacts directory.
#[derive(Debug)]
pub struct ArtifactRegistry {
    pub dir: PathBuf,
    pub specs: Vec<ArtifactSpec>,
}

impl ArtifactRegistry {
    /// Scan a directory: reads `manifest.txt` when present, otherwise
    /// globs `*.hlo.txt`.
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        if !dir.is_dir() {
            return Err(anyhow!(
                "artifacts directory {} does not exist — run `make artifacts`",
                dir.display()
            ));
        }
        let manifest = dir.join("manifest.txt");
        let mut specs = Vec::new();
        if manifest.is_file() {
            let text = std::fs::read_to_string(&manifest)
                .with_context(|| format!("reading {}", manifest.display()))?;
            for line in text.lines() {
                let mut it = line.split_whitespace();
                if let Some(file) = it.next() {
                    if file.ends_with(".hlo.txt") {
                        specs.push(ArtifactSpec {
                            name: file.trim_end_matches(".hlo.txt").to_string(),
                            file: file.to_string(),
                            detail: it.collect::<Vec<_>>().join(" "),
                        });
                    }
                }
            }
        } else {
            for entry in std::fs::read_dir(&dir)? {
                let p = entry?.path();
                let fname = p
                    .file_name()
                    .and_then(|s| s.to_str())
                    .unwrap_or_default()
                    .to_string();
                if fname.ends_with(".hlo.txt") {
                    specs.push(ArtifactSpec {
                        name: fname.trim_end_matches(".hlo.txt").to_string(),
                        file: fname,
                        detail: String::new(),
                    });
                }
            }
            specs.sort_by(|a, b| a.name.cmp(&b.name));
        }
        Ok(Self { dir, specs })
    }

    /// Full path of an artifact by logical name.
    pub fn path(&self, name: &str) -> Result<PathBuf> {
        let spec = self
            .specs
            .iter()
            .find(|s| s.name == name)
            .ok_or_else(|| {
                anyhow!(
                    "unknown artifact {name:?}; available: {}",
                    self.specs
                        .iter()
                        .map(|s| s.name.as_str())
                        .collect::<Vec<_>>()
                        .join(", ")
                )
            })?;
        Ok(self.dir.join(&spec.file))
    }

    /// Names of all artifacts.
    pub fn names(&self) -> Vec<&str> {
        self.specs.iter().map(|s| s.name.as_str()).collect()
    }

    /// Parse the batch size from a manifest detail like `in=x[32,64]f32`.
    pub fn batch_of(&self, name: &str) -> Option<usize> {
        let spec = self.specs.iter().find(|s| s.name == name)?;
        let detail = &spec.detail;
        let start = detail.find("x[")? + 2;
        let rest = &detail[start..];
        let comma = rest.find(',')?;
        rest[..comma].parse().ok()
    }

    /// Group artifacts by prefix (dof / hessian / pinn) for display.
    pub fn grouped(&self) -> BTreeMap<String, Vec<&ArtifactSpec>> {
        let mut map: BTreeMap<String, Vec<&ArtifactSpec>> = BTreeMap::new();
        for s in &self.specs {
            let group = s.name.split('_').next().unwrap_or("misc").to_string();
            map.entry(group).or_default().push(s);
        }
        map
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fixture_dir() -> PathBuf {
        let dir = std::env::temp_dir().join(format!("dof_artifacts_{}", std::process::id()));
        let _ = std::fs::create_dir_all(&dir);
        std::fs::write(
            dir.join("manifest.txt"),
            "dof_mlp_elliptic.hlo.txt in=x[32,64]f32 out=(phi,lphi) rank=64\n\
             weights.dofw dims=[64,1]\n\
             pinn_heat_step.hlo.txt in=(theta[100],x[128,3])f32 out=(loss,grad)\n",
        )
        .unwrap();
        std::fs::write(dir.join("dof_mlp_elliptic.hlo.txt"), "HloModule m\n").unwrap();
        std::fs::write(dir.join("pinn_heat_step.hlo.txt"), "HloModule p\n").unwrap();
        dir
    }

    #[test]
    fn manifest_parsing_and_lookup() {
        let dir = fixture_dir();
        let reg = ArtifactRegistry::open(&dir).unwrap();
        assert_eq!(reg.names(), vec!["dof_mlp_elliptic", "pinn_heat_step"]);
        assert!(reg.path("dof_mlp_elliptic").unwrap().is_file());
        assert!(reg.path("nope").is_err());
        assert_eq!(reg.batch_of("dof_mlp_elliptic"), Some(32));
        assert_eq!(reg.batch_of("pinn_heat_step"), Some(128));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn missing_dir_is_friendly_error() {
        let err = ArtifactRegistry::open("/definitely/not/here").unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }
}
