//! Compiled-executable cache and typed execution helpers over the PJRT
//! CPU client.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{anyhow, Context, Result};

/// Owns the PJRT client and a cache of compiled executables.
///
/// PJRT handles are not `Send`; an [`Executor`] lives on one thread (the
/// coordinator gives each model-worker thread its own).
pub struct Executor {
    client: xla::PjRtClient,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Executor {
    /// Create a CPU-backed executor.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self {
            client,
            cache: HashMap::new(),
        })
    }

    /// Platform string (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO-text artifact, caching by name.
    pub fn load(&mut self, name: &str, path: &Path) -> Result<()> {
        if self.cache.contains_key(name) {
            return Ok(());
        }
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| anyhow!("non-utf8 path {path:?}"))?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        self.cache.insert(name.to_string(), exe);
        Ok(())
    }

    /// Is an executable cached?
    pub fn is_loaded(&self, name: &str) -> bool {
        self.cache.contains_key(name)
    }

    /// Execute a loaded artifact on f32 inputs.
    ///
    /// `inputs`: (flat data, dims) per parameter, row-major. Returns the
    /// flattened f32 contents of every tuple element (AOT lowers with
    /// `return_tuple=True`, so the single output is a tuple).
    pub fn run_f32(
        &self,
        name: &str,
        inputs: &[(&[f32], &[usize])],
    ) -> Result<Vec<Vec<f32>>> {
        let exe = self
            .cache
            .get(name)
            .ok_or_else(|| anyhow!("artifact {name:?} not loaded"))?;
        let mut literals = Vec::with_capacity(inputs.len());
        for (data, dims) in inputs {
            let expect: usize = dims.iter().product();
            if expect != data.len() {
                return Err(anyhow!(
                    "input length {} != shape {:?} product {}",
                    data.len(),
                    dims,
                    expect
                ));
            }
            let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(data)
                .reshape(&dims_i64)
                .map_err(|e| anyhow!("reshape to {dims:?}: {e:?}"))?;
            literals.push(lit);
        }
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("executing {name}: {e:?}"))?;
        let out = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching result of {name}: {e:?}"))?;
        let elems = out
            .to_tuple()
            .map_err(|e| anyhow!("untupling result of {name}: {e:?}"))?;
        elems
            .into_iter()
            .map(|l| l.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}")))
            .collect()
    }
}

/// Pad a `[rows, width]` row-major batch with zero rows up to `target`
/// rows; returns the padded flat buffer.
pub fn pad_batch(data: &[f32], rows: usize, width: usize, target: usize) -> Vec<f32> {
    assert_eq!(data.len(), rows * width, "flat batch length mismatch");
    assert!(rows <= target, "batch {rows} exceeds artifact batch {target}");
    let mut out = vec![0.0f32; target * width];
    out[..data.len()].copy_from_slice(data);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pad_batch_zero_fills() {
        let d = vec![1.0, 2.0, 3.0, 4.0];
        let p = pad_batch(&d, 2, 2, 4);
        assert_eq!(p.len(), 8);
        assert_eq!(&p[..4], &d[..]);
        assert_eq!(&p[4..], &[0.0; 4]);
    }

    #[test]
    #[should_panic]
    fn pad_batch_rejects_oversize() {
        let d = vec![0.0; 6];
        let _ = pad_batch(&d, 3, 2, 2);
    }

    // End-to-end executor tests live in rust/tests/xla_cross_check.rs —
    // they need the artifacts directory and the PJRT runtime, which are
    // integration-level concerns.
}
