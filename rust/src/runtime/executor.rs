//! Compiled-executable cache and typed execution helpers over the PJRT
//! CPU client.
//!
//! The real PJRT-backed [`Executor`] needs the external `xla` crate, which
//! is not vendored in this offline build; it is therefore gated behind the
//! `pjrt` cargo feature (see `Cargo.toml`). Enabling the feature only
//! selects this implementation — building it additionally requires adding
//! `xla` under `[dependencies]` in an environment that can supply the
//! crate. Without the feature, a stub with the identical API is compiled
//! whose constructor returns a descriptive error — callers (the CLI's
//! `bench xla` / `serve` paths, the cross-check tests) already treat
//! executor construction as fallible and skip or surface the error cleanly.

#[cfg(feature = "pjrt")]
mod pjrt_impl {
    use std::collections::HashMap;
    use std::path::Path;

    use anyhow::{anyhow, Context, Result};

    /// Owns the PJRT client and a cache of compiled executables.
    ///
    /// PJRT handles are not `Send`; an [`Executor`] lives on one thread (the
    /// coordinator gives each model-worker thread its own).
    pub struct Executor {
        client: xla::PjRtClient,
        cache: HashMap<String, xla::PjRtLoadedExecutable>,
    }

    impl Executor {
        /// Create a CPU-backed executor.
        pub fn cpu() -> Result<Self> {
            let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
            Ok(Self {
                client,
                cache: HashMap::new(),
            })
        }

        /// Platform string (diagnostics).
        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Load + compile an HLO-text artifact, caching by name.
        pub fn load(&mut self, name: &str, path: &Path) -> Result<()> {
            if self.cache.contains_key(name) {
                return Ok(());
            }
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str()
                    .ok_or_else(|| anyhow!("non-utf8 path {path:?}"))?,
            )
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling {}", path.display()))?;
            self.cache.insert(name.to_string(), exe);
            Ok(())
        }

        /// Is an executable cached?
        pub fn is_loaded(&self, name: &str) -> bool {
            self.cache.contains_key(name)
        }

        /// Execute a loaded artifact on f32 inputs.
        ///
        /// `inputs`: (flat data, dims) per parameter, row-major. Returns the
        /// flattened f32 contents of every tuple element (AOT lowers with
        /// `return_tuple=True`, so the single output is a tuple).
        pub fn run_f32(
            &self,
            name: &str,
            inputs: &[(&[f32], &[usize])],
        ) -> Result<Vec<Vec<f32>>> {
            let exe = self
                .cache
                .get(name)
                .ok_or_else(|| anyhow!("artifact {name:?} not loaded"))?;
            let mut literals = Vec::with_capacity(inputs.len());
            for (data, dims) in inputs {
                let expect: usize = dims.iter().product();
                if expect != data.len() {
                    return Err(anyhow!(
                        "input length {} != shape {:?} product {}",
                        data.len(),
                        dims,
                        expect
                    ));
                }
                let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
                let lit = xla::Literal::vec1(data)
                    .reshape(&dims_i64)
                    .map_err(|e| anyhow!("reshape to {dims:?}: {e:?}"))?;
                literals.push(lit);
            }
            let result = exe
                .execute::<xla::Literal>(&literals)
                .map_err(|e| anyhow!("executing {name}: {e:?}"))?;
            let out = result[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("fetching result of {name}: {e:?}"))?;
            let elems = out
                .to_tuple()
                .map_err(|e| anyhow!("untupling result of {name}: {e:?}"))?;
            elems
                .into_iter()
                .map(|l| l.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}")))
                .collect()
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod stub_impl {
    use std::path::Path;

    use anyhow::{anyhow, Result};

    const UNAVAILABLE: &str = "XLA/PJRT runtime unavailable: this binary was built without the \
         `pjrt` feature. Rebuild with `--features pjrt` after adding the \
         external `xla` crate to [dependencies] (it is not vendored; the \
         offline build has no registry access). The pure-Rust engines \
         (`dof bench table1/table2`, `dof serve --engine rust`) cover every \
         capability except AOT artifact execution";

    /// API-compatible stand-in for the PJRT executor; construction fails
    /// with a descriptive error.
    pub struct Executor {
        _priv: (),
    }

    impl Executor {
        /// Always fails in this build (see module docs).
        pub fn cpu() -> Result<Self> {
            Err(anyhow!(UNAVAILABLE))
        }

        /// Platform string (diagnostics).
        pub fn platform(&self) -> String {
            "unavailable (built without the pjrt feature)".to_string()
        }

        /// Unreachable in practice ([`Executor::cpu`] never succeeds).
        pub fn load(&mut self, _name: &str, _path: &Path) -> Result<()> {
            Err(anyhow!(UNAVAILABLE))
        }

        /// Is an executable cached?
        pub fn is_loaded(&self, _name: &str) -> bool {
            false
        }

        /// Unreachable in practice ([`Executor::cpu`] never succeeds).
        pub fn run_f32(
            &self,
            _name: &str,
            _inputs: &[(&[f32], &[usize])],
        ) -> Result<Vec<Vec<f32>>> {
            Err(anyhow!(UNAVAILABLE))
        }
    }
}

#[cfg(feature = "pjrt")]
pub use pjrt_impl::Executor;
#[cfg(not(feature = "pjrt"))]
pub use stub_impl::Executor;

/// Pad a `[rows, width]` row-major batch with zero rows up to `target`
/// rows; returns the padded flat buffer.
pub fn pad_batch(data: &[f32], rows: usize, width: usize, target: usize) -> Vec<f32> {
    assert_eq!(data.len(), rows * width, "flat batch length mismatch");
    assert!(rows <= target, "batch {rows} exceeds artifact batch {target}");
    let mut out = vec![0.0f32; target * width];
    out[..data.len()].copy_from_slice(data);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pad_batch_zero_fills() {
        let d = vec![1.0, 2.0, 3.0, 4.0];
        let p = pad_batch(&d, 2, 2, 4);
        assert_eq!(p.len(), 8);
        assert_eq!(&p[..4], &d[..]);
        assert_eq!(&p[4..], &[0.0; 4]);
    }

    #[test]
    #[should_panic]
    fn pad_batch_rejects_oversize() {
        let d = vec![0.0; 6];
        let _ = pad_batch(&d, 3, 2, 2);
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_reports_missing_feature() {
        let err = Executor::cpu().unwrap_err();
        assert!(err.to_string().contains("pjrt"));
    }

    // End-to-end executor tests live in rust/tests/xla_cross_check.rs —
    // they need the artifacts directory and the PJRT runtime, which are
    // integration-level concerns.
}
