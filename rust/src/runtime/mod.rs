//! XLA-PJRT runtime: load AOT-compiled HLO-text artifacts (produced by
//! `python/compile/aot.py`) and execute them from the Rust hot path.
//!
//! Python never runs here — after `make artifacts` the `dof` binary is
//! self-contained. The interchange format is HLO *text* (the published
//! xla crate's xla_extension 0.5.1 rejects jax≥0.5's 64-bit-id protos; the
//! text parser reassigns ids — see /opt/xla-example/README.md).
//!
//! PJRT execution is gated behind the `pjrt` cargo feature and additionally
//! requires adding the external `xla` crate to `[dependencies]` (it is not
//! vendored); the default build ships an API-compatible stub whose
//! constructor fails with a descriptive error (see [`executor`]).

pub mod artifact;
pub mod executor;

pub use artifact::{ArtifactRegistry, ArtifactSpec};
pub use executor::{pad_batch, Executor};
