//! Stable-Rust chunked "lane" helpers — the single home for every
//! elementwise inner loop in the crate.
//!
//! Each helper walks its slices in explicit [`LANES`]-wide chunks
//! (`chunks_exact` over fixed-size `[f64; LANES]` arrays, so the compiler
//! sees a branch-free fixed-trip inner loop and vectorizes it on stable
//! Rust — no nightly `std::simd`) followed by a scalar tail over the
//! remainder. The per-element arithmetic expression is written once per
//! helper and is **identical between the lane body and the tail**, so the
//! chunked sweep is bit-for-bit the scalar sweep for every length —
//! elementwise ops carry no cross-element accumulation, hence no
//! summation-order hazard. (Reductions — `sum`, `dot`, `norm_sq` — are
//! deliberately *not* chunked: lane-wise partial sums would change the
//! accumulation order and break the bitwise oracles.)
//!
//! The [`scalar`] submodule retains plain one-element-at-a-time twins of
//! every helper. They are not called by the engines; they exist so
//! `rust/tests/simd_tails.rs` can assert `chunked ≡ scalar` bitwise at
//! awkward (non-multiple-of-[`LANES`]) lengths.

/// Lane width of the chunked sweeps. Eight f64 lanes span two AVX2
/// registers or one AVX-512 register; narrower targets split the fixed
/// 8-trip body into as many native vectors as fit.
pub const LANES: usize = 8;

#[inline(always)]
fn lane_zip2(dst: &mut [f64], a: &[f64], mut f: impl FnMut(&mut f64, f64)) {
    debug_assert_eq!(dst.len(), a.len());
    let mut d = dst.chunks_exact_mut(LANES);
    let mut x = a.chunks_exact(LANES);
    for (d, x) in (&mut d).zip(&mut x) {
        let d: &mut [f64; LANES] = d.try_into().unwrap();
        let x: &[f64; LANES] = x.try_into().unwrap();
        for (d, &x) in d.iter_mut().zip(x) {
            f(d, x);
        }
    }
    for (d, &x) in d.into_remainder().iter_mut().zip(x.remainder()) {
        f(d, x);
    }
}

#[inline(always)]
fn lane_zip3(dst: &mut [f64], a: &[f64], b: &[f64], mut f: impl FnMut(&mut f64, f64, f64)) {
    debug_assert_eq!(dst.len(), a.len());
    debug_assert_eq!(dst.len(), b.len());
    let mut d = dst.chunks_exact_mut(LANES);
    let mut x = a.chunks_exact(LANES);
    let mut y = b.chunks_exact(LANES);
    for ((d, x), y) in (&mut d).zip(&mut x).zip(&mut y) {
        let d: &mut [f64; LANES] = d.try_into().unwrap();
        let x: &[f64; LANES] = x.try_into().unwrap();
        let y: &[f64; LANES] = y.try_into().unwrap();
        for ((d, &x), &y) in d.iter_mut().zip(x).zip(y) {
            f(d, x, y);
        }
    }
    for ((d, &x), &y) in d
        .into_remainder()
        .iter_mut()
        .zip(x.remainder())
        .zip(y.remainder())
    {
        f(d, x, y);
    }
}

#[inline(always)]
#[allow(clippy::type_complexity)]
fn lane_zip5(
    dst: &mut [f64],
    a: &[f64],
    b: &[f64],
    c: &[f64],
    e: &[f64],
    mut f: impl FnMut(&mut f64, f64, f64, f64, f64),
) {
    debug_assert_eq!(dst.len(), a.len());
    debug_assert_eq!(dst.len(), b.len());
    debug_assert_eq!(dst.len(), c.len());
    debug_assert_eq!(dst.len(), e.len());
    let mut d = dst.chunks_exact_mut(LANES);
    let mut xa = a.chunks_exact(LANES);
    let mut xb = b.chunks_exact(LANES);
    let mut xc = c.chunks_exact(LANES);
    let mut xe = e.chunks_exact(LANES);
    for ((((d, xa), xb), xc), xe) in (&mut d).zip(&mut xa).zip(&mut xb).zip(&mut xc).zip(&mut xe) {
        let d: &mut [f64; LANES] = d.try_into().unwrap();
        let xa: &[f64; LANES] = xa.try_into().unwrap();
        let xb: &[f64; LANES] = xb.try_into().unwrap();
        let xc: &[f64; LANES] = xc.try_into().unwrap();
        let xe: &[f64; LANES] = xe.try_into().unwrap();
        for ((((d, &xa), &xb), &xc), &xe) in
            d.iter_mut().zip(xa).zip(xb).zip(xc).zip(xe)
        {
            f(d, xa, xb, xc, xe);
        }
    }
    for ((((d, &xa), &xb), &xc), &xe) in d
        .into_remainder()
        .iter_mut()
        .zip(xa.remainder())
        .zip(xb.remainder())
        .zip(xc.remainder())
        .zip(xe.remainder())
    {
        f(d, xa, xb, xc, xe);
    }
}

/// `dst[i] = a[i] + b[i]`.
#[inline]
pub fn add_into(dst: &mut [f64], a: &[f64], b: &[f64]) {
    lane_zip3(dst, a, b, |d, x, y| *d = x + y);
}

/// `dst[i] = a[i] - b[i]`.
#[inline]
pub fn sub_into(dst: &mut [f64], a: &[f64], b: &[f64]) {
    lane_zip3(dst, a, b, |d, x, y| *d = x - y);
}

/// `dst[i] = a[i] * b[i]`.
#[inline]
pub fn mul_into(dst: &mut [f64], a: &[f64], b: &[f64]) {
    lane_zip3(dst, a, b, |d, x, y| *d = x * y);
}

/// `dst[i] = a[i] * s`.
#[inline]
pub fn scale_into(dst: &mut [f64], a: &[f64], s: f64) {
    lane_zip2(dst, a, |d, x| *d = x * s);
}

/// `dst[i] += a[i]`.
#[inline]
pub fn add_assign(dst: &mut [f64], a: &[f64]) {
    lane_zip2(dst, a, |d, x| *d += x);
}

/// `dst[i] *= a[i]`.
#[inline]
pub fn mul_assign(dst: &mut [f64], a: &[f64]) {
    lane_zip2(dst, a, |d, x| *d *= x);
}

/// `dst[i] += alpha * a[i]` (AXPY).
#[inline]
pub fn axpy(dst: &mut [f64], alpha: f64, a: &[f64]) {
    lane_zip2(dst, a, |d, x| *d += alpha * x);
}

/// `dst[i] += a[i] * b[i]`.
#[inline]
pub fn mul_acc(dst: &mut [f64], a: &[f64], b: &[f64]) {
    lane_zip3(dst, a, b, |d, x, y| *d += x * y);
}

/// `dst[i] += k * a[i] * b[i]` (left-associated, `(k·a)·b`).
#[inline]
pub fn scaled_mul_acc(dst: &mut [f64], k: f64, a: &[f64], b: &[f64]) {
    lane_zip3(dst, a, b, |d, x, y| *d += k * x * y);
}

/// `dst[i] += k * a[i] * a[i]` (left-associated, `(k·a)·a`).
#[inline]
pub fn scaled_sq_acc(dst: &mut [f64], k: f64, a: &[f64]) {
    lane_zip2(dst, a, |d, x| *d += k * x * x);
}

/// `dst[i] = a[i]*b[i] + c[i]*e[i]` — the fused two-product form shared by
/// the activation scalar stream and the Hessian activation reverse kernel.
#[inline]
pub fn mul_mul_add_into(dst: &mut [f64], a: &[f64], b: &[f64], c: &[f64], e: &[f64]) {
    lane_zip5(dst, a, b, c, e, |d, xa, xb, xc, xe| *d = xa * xb + xc * xe);
}

/// Plain scalar twins of every lane helper, retained as the bitwise
/// reference for `rust/tests/simd_tails.rs`. Each body is the textbook
/// one-element loop with the *same* per-element expression as the chunked
/// helper above it.
#[doc(hidden)]
pub mod scalar {
    pub fn add_into(dst: &mut [f64], a: &[f64], b: &[f64]) {
        for ((d, &x), &y) in dst.iter_mut().zip(a).zip(b) {
            *d = x + y;
        }
    }

    pub fn sub_into(dst: &mut [f64], a: &[f64], b: &[f64]) {
        for ((d, &x), &y) in dst.iter_mut().zip(a).zip(b) {
            *d = x - y;
        }
    }

    pub fn mul_into(dst: &mut [f64], a: &[f64], b: &[f64]) {
        for ((d, &x), &y) in dst.iter_mut().zip(a).zip(b) {
            *d = x * y;
        }
    }

    pub fn scale_into(dst: &mut [f64], a: &[f64], s: f64) {
        for (d, &x) in dst.iter_mut().zip(a) {
            *d = x * s;
        }
    }

    pub fn add_assign(dst: &mut [f64], a: &[f64]) {
        for (d, &x) in dst.iter_mut().zip(a) {
            *d += x;
        }
    }

    pub fn mul_assign(dst: &mut [f64], a: &[f64]) {
        for (d, &x) in dst.iter_mut().zip(a) {
            *d *= x;
        }
    }

    pub fn axpy(dst: &mut [f64], alpha: f64, a: &[f64]) {
        for (d, &x) in dst.iter_mut().zip(a) {
            *d += alpha * x;
        }
    }

    pub fn mul_acc(dst: &mut [f64], a: &[f64], b: &[f64]) {
        for ((d, &x), &y) in dst.iter_mut().zip(a).zip(b) {
            *d += x * y;
        }
    }

    pub fn scaled_mul_acc(dst: &mut [f64], k: f64, a: &[f64], b: &[f64]) {
        for ((d, &x), &y) in dst.iter_mut().zip(a).zip(b) {
            *d += k * x * y;
        }
    }

    pub fn scaled_sq_acc(dst: &mut [f64], k: f64, a: &[f64]) {
        for (d, &x) in dst.iter_mut().zip(a) {
            *d += k * x * x;
        }
    }

    pub fn mul_mul_add_into(dst: &mut [f64], a: &[f64], b: &[f64], c: &[f64], e: &[f64]) {
        for ((((d, &xa), &xb), &xc), &xe) in dst.iter_mut().zip(a).zip(b).zip(c).zip(e) {
            *d = xa * xb + xc * xe;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Xoshiro256;

    fn randv(rng: &mut Xoshiro256, n: usize) -> Vec<f64> {
        (0..n).map(|_| rng.normal()).collect()
    }

    /// Every helper, bit-identical to its scalar twin at lengths straddling
    /// the lane width (the dedicated tail suite widens this to the engine
    /// level; this is the in-crate smoke check).
    #[test]
    fn chunked_matches_scalar_at_awkward_lengths() {
        let mut rng = Xoshiro256::new(0x1a7e5);
        for n in [0usize, 1, 3, 7, 8, 9, 15, 16, 17, 31, 33] {
            let a = randv(&mut rng, n);
            let b = randv(&mut rng, n);
            let c = randv(&mut rng, n);
            let e = randv(&mut rng, n);
            let seed = randv(&mut rng, n);
            let k = rng.normal();

            let mut got = seed.clone();
            let mut want = seed.clone();
            add_into(&mut got, &a, &b);
            scalar::add_into(&mut want, &a, &b);
            assert_eq!(got, want, "add_into n={n}");

            got.copy_from_slice(&seed);
            want.copy_from_slice(&seed);
            sub_into(&mut got, &a, &b);
            scalar::sub_into(&mut want, &a, &b);
            assert_eq!(got, want, "sub_into n={n}");

            got.copy_from_slice(&seed);
            want.copy_from_slice(&seed);
            mul_into(&mut got, &a, &b);
            scalar::mul_into(&mut want, &a, &b);
            assert_eq!(got, want, "mul_into n={n}");

            got.copy_from_slice(&seed);
            want.copy_from_slice(&seed);
            scale_into(&mut got, &a, k);
            scalar::scale_into(&mut want, &a, k);
            assert_eq!(got, want, "scale_into n={n}");

            got.copy_from_slice(&seed);
            want.copy_from_slice(&seed);
            add_assign(&mut got, &a);
            scalar::add_assign(&mut want, &a);
            assert_eq!(got, want, "add_assign n={n}");

            got.copy_from_slice(&seed);
            want.copy_from_slice(&seed);
            mul_assign(&mut got, &a);
            scalar::mul_assign(&mut want, &a);
            assert_eq!(got, want, "mul_assign n={n}");

            got.copy_from_slice(&seed);
            want.copy_from_slice(&seed);
            axpy(&mut got, k, &a);
            scalar::axpy(&mut want, k, &a);
            assert_eq!(got, want, "axpy n={n}");

            got.copy_from_slice(&seed);
            want.copy_from_slice(&seed);
            mul_acc(&mut got, &a, &b);
            scalar::mul_acc(&mut want, &a, &b);
            assert_eq!(got, want, "mul_acc n={n}");

            got.copy_from_slice(&seed);
            want.copy_from_slice(&seed);
            scaled_mul_acc(&mut got, k, &a, &b);
            scalar::scaled_mul_acc(&mut want, k, &a, &b);
            assert_eq!(got, want, "scaled_mul_acc n={n}");

            got.copy_from_slice(&seed);
            want.copy_from_slice(&seed);
            scaled_sq_acc(&mut got, k, &a);
            scalar::scaled_sq_acc(&mut want, k, &a);
            assert_eq!(got, want, "scaled_sq_acc n={n}");

            got.copy_from_slice(&seed);
            want.copy_from_slice(&seed);
            mul_mul_add_into(&mut got, &a, &b, &c, &e);
            scalar::mul_mul_add_into(&mut want, &a, &b, &c, &e);
            assert_eq!(got, want, "mul_mul_add_into n={n}");
        }
    }
}
