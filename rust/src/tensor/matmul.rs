//! Cache-blocked, optionally row-parallel matrix multiplication.
//!
//! The DOF hot path is tangent propagation `G' = G Wᵀ` (an `r×k` by `m×k`ᵀ
//! product); the Hessian baseline is dominated by the same shape with
//! `r = N`. These kernels are the single biggest wall-clock contributor in
//! the Rust engine, so they are written with an i-k-j loop order (unit-stride
//! inner loop, friendly to auto-vectorization) plus `BLOCK`×`BLOCK` cache
//! blocking over the k and j dimensions.
//!
//! Large products additionally split their **output rows** across the
//! process-wide thread pool ([`crate::parallel`]). Row chunks are aligned to
//! the 4-row micro-kernel so every row sees the same grouping — and
//! therefore the same floating-point operation order — as the serial sweep,
//! keeping the parallel product bit-identical. Nested parallelism is
//! suppressed: a GEMM issued from inside a pool worker (e.g. a shard of the
//! DOF batch) always runs serially.
//!
//! ## Planned dispatch and the bitwise-summation-order contract
//!
//! The NT product (`C += A·Bᵀ`, the tangent-propagation shape) has two
//! micro-kernel forms — the dot form ([`matmul_nt_dot`]) and the
//! transpose-then-blocked-AXPY form riding [`matmul_into`]. **Every GEMM
//! output element is a single-accumulator sum over `k` in ascending order
//! starting from `+0.0`; every micro-kernel must preserve this.** Under
//! that contract the two forms are `==`-identical for every shape, so a
//! compiled program may record either form per Linear step ([`GemmPlan`],
//! chosen by [`GemmPlan::choose`] from the batch-invariant per-item shape)
//! without disturbing the bitwise oracles. Plan-less callers keep the
//! runtime `m < 32` heuristic in [`matmul_nt_into`]; planned executors
//! dispatch through [`matmul_nt_planned`], optionally over a
//! [`PackedPanel`] holding `Bᵀ` pre-transposed.

use super::Tensor;

/// Cache-block edge for the k and j dimensions, chosen empirically: with
/// `BLOCK = 128` the inner sweep keeps one 128-wide segment of a `Bᵀ` row
/// against four live `C` row segments (~5 KiB, L1-resident) while a full
/// 128×128 `Bᵀ` tile (128 KiB) stays L2-resident across the whole `i`
/// sweep; 64 halves the tile reuse per load without improving L1
/// behaviour, and 256 spills the tile out of L2 on smaller parts. The
/// sizing is unchanged by panel packing: a [`PackedPanel`] stores exactly
/// the `[k, n]` row-major `Bᵀ` this kernel consumes, so the `kk`/`jj`
/// tiles walk the packed panel with the same unit-stride access pattern
/// the ad-hoc transpose produced — packing moves the `n·k` transpose out
/// of the per-call hot path, not the blocking.
const BLOCK: usize = 128;

/// Row-parallel dispatch thresholds: below either, the spawn cost of a
/// scoped parallel region is not worth it.
///
/// For plan-less callers these remain a per-call runtime heuristic
/// ([`runtime_gemm_threads`]). Compiled programs instead record the
/// decision at plan time: [`GemmPlan::choose`] stores `parallel`
/// eligibility (the AXPY form may fan out; the dot form never does) in the
/// schedule's Linear step, and execution only re-checks the *runtime
/// clamp* — actual row count against these thresholds plus the
/// nested-parallelism guard — which depends on the shard shape, never on
/// the plan.
const PAR_MIN_ROWS: usize = 64;
const PAR_MIN_MACS: usize = 1 << 21;

/// Per-batch-item MAC threshold of [`GemmPlan::choose`]: below it the
/// `n·k` transpose (or a packed-panel's cache footprint) would rival the
/// GEMM itself and the dot form wins; above it the AXPY form's vectorized
/// unit-stride inner loop wins (see [`matmul_nt_into`]'s perf note).
pub const GEMM_DOT_MAX_MACS: usize = 4096;

/// Which NT micro-kernel a compiled Linear step runs.
///
/// Both forms satisfy the module-level summation-order contract (one
/// accumulator per output element, ascending `k`, seeded from `+0.0`), so
/// the choice is a pure performance decision — results are bit-identical
/// either way, which is what lets plans record a batch-invariant choice
/// while plan-less calls keep a row-count heuristic.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GemmForm {
    /// Dot-product form, 4 columns in flight ([`matmul_nt_dot`]): no
    /// transpose, serial; wins when the per-item product is tiny.
    Dot,
    /// Transpose-then-blocked-AXPY form ([`matmul_into`] over `Bᵀ`),
    /// fed from a [`PackedPanel`] when the caller packed one.
    PackedAxpy,
}

/// The plan-time micro-kernel choice recorded in a compiled schedule's
/// Linear step — per-call branching hoisted to compile time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GemmPlan {
    pub form: GemmForm,
    /// Whether this step may enter the row-parallel dispatcher. Recorded
    /// at plan time (the dot form is inherently serial); the runtime clamp
    /// against actual rows / nested parallelism still applies at execute.
    pub parallel: bool,
}

impl GemmPlan {
    /// Choose the micro-kernel from the **batch-invariant** per-item
    /// shape: `rows_per_item` is the tangent-row count one batch item
    /// contributes (DOF `t+2`, jet `t·(k+1)`, Hessian forward `N`), `k`/`n`
    /// the weight dims. Programs must never key on batch size or thread
    /// count, so the total row count is unavailable here by design — and
    /// irrelevant, since both forms are bit-identical.
    pub fn choose(rows_per_item: usize, k: usize, n: usize) -> Self {
        if rows_per_item * k * n < GEMM_DOT_MAX_MACS {
            GemmPlan {
                form: GemmForm::Dot,
                parallel: false,
            }
        } else {
            GemmPlan {
                form: GemmForm::PackedAxpy,
                parallel: true,
            }
        }
    }
}

impl Default for GemmPlan {
    /// Neutral pre-specialization value used by the shared schedule
    /// builder; each program compiler overwrites it per Linear step.
    fn default() -> Self {
        GemmPlan {
            form: GemmForm::PackedAxpy,
            parallel: true,
        }
    }
}

/// A cache-aware pre-transposed weight panel for the NT GEMM: `Bᵀ` in the
/// `[k, n]` row-major layout the blocked AXPY kernel consumes.
///
/// Panels hold weight **values**, and compiled programs are cached by
/// structure only (weight-value-independent — the `cache_soundness` pins),
/// so panels are *never* stored inside a cached program: engines pack once
/// per top-level call ([`crate::plan::pack_panels`]) and share the packed
/// set read-only across shards. The packed layout is bit-for-bit the
/// ad-hoc transpose [`matmul_nt_into`] performs, so packed and unpacked
/// executions are `==`-identical.
#[derive(Debug, Clone)]
pub struct PackedPanel {
    bt: Vec<f64>,
    k: usize,
    n: usize,
}

impl PackedPanel {
    /// Pack `b` (`n×k` row-major, the NT operand) into `Bᵀ` (`k×n`).
    pub fn pack(b: &[f64], k: usize, n: usize) -> Self {
        assert_eq!(b.len(), n * k, "panel operand must be n*k");
        PackedPanel {
            bt: transpose_nt(b, k, n),
            k,
            n,
        }
    }

    /// `(k, n)` dims of the packed `Bᵀ`.
    pub fn dims(&self) -> (usize, usize) {
        (self.k, self.n)
    }

    /// The packed `Bᵀ` data, `[k, n]` row-major.
    pub fn bt(&self) -> &[f64] {
        &self.bt
    }
}

/// Transpose the NT operand `b` (`n×k` row-major) into `Bᵀ` (`k×n`).
fn transpose_nt(b: &[f64], k: usize, n: usize) -> Vec<f64> {
    let mut bt = vec![0.0f64; k * n];
    for j in 0..n {
        let brow = &b[j * k..(j + 1) * k];
        for (p, &v) in brow.iter().enumerate() {
            bt[p * n + j] = v;
        }
    }
    bt
}

/// The runtime thread-count clamp shared by [`matmul_into`] and the
/// parallel-eligible planned path: serial inside a pool worker or below
/// the dispatch thresholds, the global pool width otherwise.
fn runtime_gemm_threads(m: usize, k: usize, n: usize) -> usize {
    if crate::parallel::in_worker() || m < PAR_MIN_ROWS || m * k * n < PAR_MIN_MACS {
        1
    } else {
        crate::parallel::global().threads()
    }
}

/// `C = A · B` where `A` is `m×k`, `B` is `k×n`.
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = (a.dims()[0], a.dims()[1]);
    let (k2, n) = (b.dims()[0], b.dims()[1]);
    assert_eq!(k, k2, "matmul inner dims: {k} vs {k2}");
    let mut c = Tensor::zeros(&[m, n]);
    matmul_into(a.data(), b.data(), c.data_mut(), m, k, n);
    c
}

/// Raw blocked GEMM on slices: `C[m×n] += A[m×k] · B[k×n]` (C assumed zeroed
/// by the caller when a fresh product is wanted).
///
/// Large products run row-parallel on the global pool; the result is
/// bit-identical to the serial kernel (see module docs and
/// [`matmul_into_threads`]).
pub fn matmul_into(a: &[f64], b: &[f64], c: &mut [f64], m: usize, k: usize, n: usize) {
    matmul_into_threads(a, b, c, m, k, n, runtime_gemm_threads(m, k, n));
}

/// [`matmul_into`] with an explicit worker count (1 = serial). Row chunks
/// are 4-aligned so the micro-kernel grouping — and therefore the exact
/// FP operation order per output row — matches the serial sweep.
///
/// Parallel chunks run on the **persistent worker team**
/// ([`crate::parallel::pool`]) instead of spawning scoped threads per
/// product: chunk boundaries and the per-chunk serial kernel are unchanged,
/// so the result stays bit-identical to the serial sweep (and to the old
/// scoped-spawn path) while large GEMMs stop paying per-call thread
/// creation.
pub fn matmul_into_threads(
    a: &[f64],
    b: &[f64],
    c: &mut [f64],
    m: usize,
    k: usize,
    n: usize,
    threads: usize,
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    if threads > 1 && m >= 8 {
        let ranges = crate::parallel::split_rows_aligned(m, threads, 4);
        if ranges.len() > 1 {
            // Disjoint output row chunks, written through a raw pointer the
            // pool closure can capture by value.
            struct SendPtr(*mut f64);
            unsafe impl Send for SendPtr {}
            unsafe impl Sync for SendPtr {}
            let cp = SendPtr(c.as_mut_ptr());
            let cp = &cp;
            crate::parallel::Pool::new(threads).run_sharded(ranges, |_, r| {
                let rows = r.len();
                // SAFETY: ranges partition 0..m, so every chunk
                // [r.start*n, r.end*n) is a disjoint slice of `c`, each
                // written by exactly one claimant; `c` outlives the region
                // (run_sharded blocks until all shards complete).
                let chunk = unsafe {
                    std::slice::from_raw_parts_mut(cp.0.add(r.start * n), rows * n)
                };
                matmul_into_serial(&a[r.start * k..r.end * k], b, chunk, rows, k, n);
            });
            return;
        }
    }
    matmul_into_serial(a, b, c, m, k, n);
}

/// The serial blocked kernel.
///
/// Perf (§Perf): the inner kernel processes **four rows of A per sweep** of
/// a `B` row, so each `B` load feeds four FMAs (the 1-row AXPY form is
/// L1-bandwidth-bound at ~9 GFLOP/s on this machine; the 4-row form
/// measured ~2× that).
fn matmul_into_serial(a: &[f64], b: &[f64], c: &mut [f64], m: usize, k: usize, n: usize) {
    for kk in (0..k).step_by(BLOCK) {
        let k_end = (kk + BLOCK).min(k);
        for jj in (0..n).step_by(BLOCK) {
            let j_end = (jj + BLOCK).min(n);
            let jw = j_end - jj;
            let mut i = 0;
            // 4-row micro-kernel.
            while i + 4 <= m {
                let (a0, a1, a2, a3) = (
                    &a[i * k..(i + 1) * k],
                    &a[(i + 1) * k..(i + 2) * k],
                    &a[(i + 2) * k..(i + 3) * k],
                    &a[(i + 3) * k..(i + 4) * k],
                );
                // Split c into four disjoint row slices.
                let (c01, c23) = c[i * n..(i + 4) * n].split_at_mut(2 * n);
                let (c0, c1) = c01.split_at_mut(n);
                let (c2, c3) = c23.split_at_mut(n);
                let (c0, c1, c2, c3) = (
                    &mut c0[jj..j_end],
                    &mut c1[jj..j_end],
                    &mut c2[jj..j_end],
                    &mut c3[jj..j_end],
                );
                for p in kk..k_end {
                    let (w0, w1, w2, w3) = (a0[p], a1[p], a2[p], a3[p]);
                    let brow = &b[p * n + jj..p * n + j_end];
                    // Zipped iteration removes bounds checks so the loop
                    // vectorizes to pure FMA streams.
                    for ((((cj0, cj1), cj2), cj3), &bv) in c0
                        .iter_mut()
                        .zip(c1.iter_mut())
                        .zip(c2.iter_mut())
                        .zip(c3.iter_mut())
                        .zip(brow)
                    {
                        *cj0 += w0 * bv;
                        *cj1 += w1 * bv;
                        *cj2 += w2 * bv;
                        *cj3 += w3 * bv;
                    }
                }
                let _ = jw;
                i += 4;
            }
            // Remainder rows: plain AXPY.
            while i < m {
                let arow = &a[i * k..(i + 1) * k];
                let crow = &mut c[i * n + jj..i * n + j_end];
                for p in kk..k_end {
                    let aip = arow[p];
                    if aip == 0.0 {
                        continue;
                    }
                    let brow = &b[p * n + jj..p * n + j_end];
                    for j in 0..jw {
                        crow[j] += aip * brow[j];
                    }
                }
                i += 1;
            }
        }
    }
}

/// `C = Aᵀ · B` where `A` is `k×m`, `B` is `k×n` (result `m×n`).
pub fn matmul_tn(a: &Tensor, b: &Tensor) -> Tensor {
    let (k, m) = (a.dims()[0], a.dims()[1]);
    let (k2, n) = (b.dims()[0], b.dims()[1]);
    assert_eq!(k, k2, "matmul_tn inner dims: {k} vs {k2}");
    let mut c = Tensor::zeros(&[m, n]);
    let (ad, bd, cd) = (a.data(), b.data(), c.data_mut());
    // Loop over k outer: each slice of A contributes a rank-1-style update.
    for p in 0..k {
        let arow = &ad[p * m..(p + 1) * m];
        let brow = &bd[p * n..(p + 1) * n];
        for i in 0..m {
            let aip = arow[i];
            if aip == 0.0 {
                continue;
            }
            let crow = &mut cd[i * n..(i + 1) * n];
            for j in 0..n {
                crow[j] += aip * brow[j];
            }
        }
    }
    c
}

/// `C = A · Bᵀ` where `A` is `m×k`, `B` is `n×k` (result `m×n`).
///
/// This is the DOF tangent-propagation shape (`G' = G Wᵀ` with `W: n×k`);
/// the inner loop is a dot product over unit-stride rows of both operands.
pub fn matmul_nt(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = (a.dims()[0], a.dims()[1]);
    let (n, k2) = (b.dims()[0], b.dims()[1]);
    assert_eq!(k, k2, "matmul_nt inner dims: {k} vs {k2}");
    let mut c = Tensor::zeros(&[m, n]);
    matmul_nt_into(a.data(), b.data(), c.data_mut(), m, k, n);
    c
}

/// Raw `C[m×n] += A[m×k] · B[n×k]ᵀ`.
///
/// Perf note (§Perf): the dot-product form (one accumulator per output)
/// serializes on FMA latency and measured ~3 GFLOP/s; transposing `B` once
/// (`n·k` moves, negligible against `m·k·n` MACs) and delegating to the
/// AXPY-form [`matmul_into`] vectorizes the inner loop and measured
/// ~9 GFLOP/s with `target-cpu=native`, a 2.5–3× win on the DOF hot GEMM.
pub fn matmul_nt_into(a: &[f64], b: &[f64], c: &mut [f64], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(c.len(), m * n);
    if m < 32 {
        // Few output rows (small batch × tangent width, e.g. the sparse
        // architecture's per-block streams): the n·k transpose would rival
        // the GEMM itself.
        matmul_nt_dot(a, b, c, m, k, n);
        return;
    }
    // Transpose B (n×k, row-major) into Bᵀ (k×n), then the blocked
    // AXPY-form kernel (see matmul_into's perf note).
    let bt = transpose_nt(b, k, n);
    matmul_into(a, &bt, c, m, k, n);
}

/// Dot-product form of the NT GEMM, 4 columns in flight so the `a` row
/// feeds four accumulator chains. One accumulator per output element,
/// ascending `p`, seeded from `+0.0` — the summation-order contract.
pub fn matmul_nt_dot(a: &[f64], b: &[f64], c: &mut [f64], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(c.len(), m * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        let mut j = 0;
        while j + 4 <= n {
            let (b0, b1, b2, b3) = (
                &b[j * k..(j + 1) * k],
                &b[(j + 1) * k..(j + 2) * k],
                &b[(j + 2) * k..(j + 3) * k],
                &b[(j + 3) * k..(j + 4) * k],
            );
            let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
            for p in 0..k {
                let av = arow[p];
                s0 += av * b0[p];
                s1 += av * b1[p];
                s2 += av * b2[p];
                s3 += av * b3[p];
            }
            crow[j] += s0;
            crow[j + 1] += s1;
            crow[j + 2] += s2;
            crow[j + 3] += s3;
            j += 4;
        }
        while j < n {
            let brow = &b[j * k..(j + 1) * k];
            let mut acc = 0.0;
            for p in 0..k {
                acc += arow[p] * brow[p];
            }
            crow[j] += acc;
            j += 1;
        }
    }
}

/// The planned NT GEMM: dispatch on a compiled [`GemmPlan`] instead of the
/// runtime `m < 32` heuristic, reading `Bᵀ` from a pre-packed
/// [`PackedPanel`] when the caller holds one (falling back to an ad-hoc
/// transpose otherwise — same bits either way).
#[allow(clippy::too_many_arguments)]
pub fn matmul_nt_planned(
    a: &[f64],
    b: &[f64],
    panel: Option<&PackedPanel>,
    plan: GemmPlan,
    c: &mut [f64],
    m: usize,
    k: usize,
    n: usize,
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(c.len(), m * n);
    match plan.form {
        GemmForm::Dot => matmul_nt_dot(a, b, c, m, k, n),
        GemmForm::PackedAxpy => {
            let threads = if plan.parallel {
                runtime_gemm_threads(m, k, n)
            } else {
                1
            };
            match panel {
                Some(p) => {
                    assert_eq!(p.dims(), (k, n), "packed panel dims mismatch");
                    matmul_into_threads(a, p.bt(), c, m, k, n, threads);
                }
                None => {
                    let bt = transpose_nt(b, k, n);
                    matmul_into_threads(a, &bt, c, m, k, n, threads);
                }
            }
        }
    }
}

/// Matrix–vector product `y = A·x` (`A: m×n`).
/// Exposed for examples and the PDE module.
pub fn matvec(a: &Tensor, x: &[f64]) -> Vec<f64> {
    let (m, n) = (a.dims()[0], a.dims()[1]);
    assert_eq!(x.len(), n);
    let ad = a.data();
    (0..m)
        .map(|i| {
            let row = &ad[i * n..(i + 1) * n];
            row.iter().zip(x).map(|(&a, &b)| a * b).sum()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Xoshiro256;

    fn naive(a: &Tensor, b: &Tensor) -> Tensor {
        let (m, k) = (a.dims()[0], a.dims()[1]);
        let n = b.dims()[1];
        let mut c = Tensor::zeros(&[m, n]);
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0;
                for p in 0..k {
                    s += a.at(i, p) * b.at(p, j);
                }
                c.set(i, j, s);
            }
        }
        c
    }

    fn assert_close(a: &Tensor, b: &Tensor, tol: f64) {
        assert_eq!(a.dims(), b.dims());
        for (x, y) in a.data().iter().zip(b.data()) {
            assert!((x - y).abs() < tol, "{x} vs {y}");
        }
    }

    #[test]
    fn matmul_matches_naive_various_sizes() {
        let mut rng = Xoshiro256::new(1);
        for &(m, k, n) in &[(1, 1, 1), (3, 5, 7), (64, 64, 64), (65, 130, 33), (128, 17, 96)] {
            let a = Tensor::randn(&[m, k], &mut rng);
            let b = Tensor::randn(&[k, n], &mut rng);
            assert_close(&matmul(&a, &b), &naive(&a, &b), 1e-9);
        }
    }

    #[test]
    fn transposed_variants_match() {
        let mut rng = Xoshiro256::new(2);
        let a = Tensor::randn(&[20, 33], &mut rng);
        let b = Tensor::randn(&[33, 14], &mut rng);
        // A·B via matmul_tn(Aᵀ, B)
        let at = a.transpose();
        assert_close(&matmul_tn(&at, &b), &matmul(&a, &b), 1e-9);
        // A·B via matmul_nt(A, Bᵀ)
        let bt = b.transpose();
        assert_close(&matmul_nt(&a, &bt), &matmul(&a, &b), 1e-9);
    }

    #[test]
    fn matvec_matches_matmul() {
        let mut rng = Xoshiro256::new(3);
        let a = Tensor::randn(&[9, 6], &mut rng);
        let x = Tensor::randn(&[6, 1], &mut rng);
        let y = matvec(&a, x.data());
        let y2 = matmul(&a, &x);
        for i in 0..9 {
            assert!((y[i] - y2.at(i, 0)).abs() < 1e-10);
        }
    }

    #[test]
    fn parallel_rows_bit_identical_to_serial() {
        let mut rng = Xoshiro256::new(5);
        // Sizes straddling the 4-row alignment and the remainder path.
        for &(m, k, n) in &[(97, 64, 51), (128, 33, 40), (66, 80, 19)] {
            let a = Tensor::randn(&[m, k], &mut rng);
            let b = Tensor::randn(&[k, n], &mut rng);
            let mut serial = vec![0.0; m * n];
            matmul_into_threads(a.data(), b.data(), &mut serial, m, k, n, 1);
            for threads in [2usize, 3, 4, 8] {
                let mut par = vec![0.0; m * n];
                matmul_into_threads(a.data(), b.data(), &mut par, m, k, n, threads);
                assert_eq!(serial, par, "threads={threads} m={m} k={k} n={n}");
            }
        }
    }

    #[test]
    fn nt_forms_bit_identical_packed_and_unpacked() {
        let mut rng = Xoshiro256::new(6);
        // Shapes straddling the old m<32 heuristic, the 4-column dot path,
        // and non-multiple-of-8 widths.
        for &(m, k, n) in &[
            (1, 1, 1),
            (3, 5, 7),
            (31, 9, 5),
            (32, 9, 5),
            (40, 17, 33),
            (97, 12, 19),
        ] {
            let a = Tensor::randn(&[m, k], &mut rng);
            let b = Tensor::randn(&[n, k], &mut rng);
            let mut want = vec![0.0; m * n];
            matmul_nt_into(a.data(), b.data(), &mut want, m, k, n);
            let panel = PackedPanel::pack(b.data(), k, n);
            let dot = GemmPlan {
                form: GemmForm::Dot,
                parallel: false,
            };
            let axpy = GemmPlan {
                form: GemmForm::PackedAxpy,
                parallel: true,
            };
            for (plan, pp) in [
                (dot, None),
                (axpy, None),
                (axpy, Some(&panel)),
            ] {
                let mut got = vec![0.0; m * n];
                matmul_nt_planned(a.data(), b.data(), pp, plan, &mut got, m, k, n);
                assert_eq!(
                    got, want,
                    "plan={plan:?} packed={} m={m} k={k} n={n}",
                    pp.is_some()
                );
            }
        }
    }

    #[test]
    fn packed_panel_is_the_adhoc_transpose() {
        let mut rng = Xoshiro256::new(7);
        let (k, n) = (13, 9);
        let b = Tensor::randn(&[n, k], &mut rng);
        let panel = PackedPanel::pack(b.data(), k, n);
        assert_eq!(panel.dims(), (k, n));
        assert_eq!(panel.bt(), transpose_nt(b.data(), k, n).as_slice());
    }

    #[test]
    fn gemm_plan_choice_is_shape_driven() {
        // Tiny per-item products stay in dot form; the fused-MLP hot shape
        // goes packed. The exact threshold is a perf knob — the invariant
        // is batch-invariance and that both forms agree bitwise (above).
        assert_eq!(GemmPlan::choose(4, 6, 6).form, GemmForm::Dot);
        assert!(!GemmPlan::choose(4, 6, 6).parallel);
        assert_eq!(GemmPlan::choose(66, 64, 64).form, GemmForm::PackedAxpy);
        assert!(GemmPlan::choose(66, 64, 64).parallel);
    }

    #[test]
    fn identity_is_noop() {
        let mut rng = Xoshiro256::new(4);
        let a = Tensor::randn(&[10, 10], &mut rng);
        assert_close(&matmul(&a, &Tensor::eye(10)), &a, 1e-12);
        assert_close(&matmul(&Tensor::eye(10), &a), &a, 1e-12);
    }
}
