//! Dense tensor substrate.
//!
//! A deliberately small, fast, row-major dense tensor over `f64`, sufficient
//! for the DOF/Hessian execution engines, the PDE training loop, and the
//! bench harness. No external BLAS: `matmul` uses a cache-blocked
//! micro-kernel (see [`matmul`]).

pub mod lanes;
mod matmul;
mod ops;
mod shape;

pub use matmul::{
    matmul, matmul_into, matmul_into_threads, matmul_nt, matmul_nt_dot, matmul_nt_into,
    matmul_nt_planned, matmul_tn, matvec, GemmForm, GemmPlan, PackedPanel, GEMM_DOT_MAX_MACS,
};
pub use shape::Shape;

use crate::util::Xoshiro256;

/// Row-major dense tensor of `f64`.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    shape: Shape,
    data: Vec<f64>,
}

impl Tensor {
    /// Zero-filled tensor.
    pub fn zeros(dims: &[usize]) -> Self {
        let shape = Shape::new(dims);
        let n = shape.numel();
        Self {
            shape,
            data: vec![0.0; n],
        }
    }

    /// Constant-filled tensor.
    pub fn full(dims: &[usize], v: f64) -> Self {
        let shape = Shape::new(dims);
        let n = shape.numel();
        Self {
            shape,
            data: vec![v; n],
        }
    }

    /// Build from existing data; panics on length mismatch.
    pub fn from_vec(dims: &[usize], data: Vec<f64>) -> Self {
        let shape = Shape::new(dims);
        assert_eq!(
            shape.numel(),
            data.len(),
            "shape {:?} needs {} elements, got {}",
            dims,
            shape.numel(),
            data.len()
        );
        Self { shape, data }
    }

    /// 1-D tensor from a slice.
    pub fn vector(xs: &[f64]) -> Self {
        Self::from_vec(&[xs.len()], xs.to_vec())
    }

    /// 2-D tensor from rows; panics if ragged.
    pub fn matrix(rows: &[Vec<f64>]) -> Self {
        let r = rows.len();
        let c = if r == 0 { 0 } else { rows[0].len() };
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c, "ragged matrix rows");
            data.extend_from_slice(row);
        }
        Self::from_vec(&[r, c], data)
    }

    /// Identity matrix of size `n`.
    pub fn eye(n: usize) -> Self {
        let mut t = Self::zeros(&[n, n]);
        for i in 0..n {
            t.data[i * n + i] = 1.0;
        }
        t
    }

    /// i.i.d. N(0,1) entries.
    pub fn randn(dims: &[usize], rng: &mut Xoshiro256) -> Self {
        let shape = Shape::new(dims);
        let n = shape.numel();
        let data = (0..n).map(|_| rng.normal()).collect();
        Self { shape, data }
    }

    /// i.i.d. U[lo,hi) entries.
    pub fn rand_uniform(dims: &[usize], lo: f64, hi: f64, rng: &mut Xoshiro256) -> Self {
        let shape = Shape::new(dims);
        let n = shape.numel();
        let data = (0..n).map(|_| rng.uniform(lo, hi)).collect();
        Self { shape, data }
    }

    // ---- accessors -------------------------------------------------------

    pub fn shape(&self) -> &Shape {
        &self.shape
    }

    pub fn dims(&self) -> &[usize] {
        self.shape.dims()
    }

    pub fn rank(&self) -> usize {
        self.shape.rank()
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn data(&self) -> &[f64] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f64> {
        self.data
    }

    /// Scalar extraction; panics unless numel == 1.
    pub fn item(&self) -> f64 {
        assert_eq!(self.numel(), 1, "item() on non-scalar tensor");
        self.data[0]
    }

    /// 2-D element access.
    pub fn at(&self, i: usize, j: usize) -> f64 {
        debug_assert_eq!(self.rank(), 2);
        let c = self.dims()[1];
        self.data[i * c + j]
    }

    /// 2-D element mutation.
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        debug_assert_eq!(self.rank(), 2);
        let c = self.dims()[1];
        self.data[i * c + j] = v;
    }

    /// Reshape (same numel), returning a new view-by-copy of the metadata.
    pub fn reshape(&self, dims: &[usize]) -> Tensor {
        let shape = Shape::new(dims);
        assert_eq!(shape.numel(), self.numel(), "reshape numel mismatch");
        Tensor {
            shape,
            data: self.data.clone(),
        }
    }

    /// Row `i` of a 2-D tensor as a slice.
    pub fn row(&self, i: usize) -> &[f64] {
        assert_eq!(self.rank(), 2);
        let c = self.dims()[1];
        &self.data[i * c..(i + 1) * c]
    }

    /// Mutable row.
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        assert_eq!(self.rank(), 2);
        let c = self.dims()[1];
        &mut self.data[i * c..(i + 1) * c]
    }

    /// Transpose of a 2-D tensor.
    pub fn transpose(&self) -> Tensor {
        assert_eq!(self.rank(), 2);
        let (r, c) = (self.dims()[0], self.dims()[1]);
        let mut out = Tensor::zeros(&[c, r]);
        for i in 0..r {
            for j in 0..c {
                out.data[j * r + i] = self.data[i * c + j];
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_access() {
        let t = Tensor::matrix(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        assert_eq!(t.dims(), &[2, 2]);
        assert_eq!(t.at(1, 0), 3.0);
        assert_eq!(t.transpose().at(0, 1), 3.0);
    }

    #[test]
    fn eye_and_item() {
        let i = Tensor::eye(3);
        assert_eq!(i.at(2, 2), 1.0);
        assert_eq!(i.at(0, 1), 0.0);
        let s = Tensor::from_vec(&[1], vec![7.0]);
        assert_eq!(s.item(), 7.0);
    }

    #[test]
    #[should_panic]
    fn from_vec_mismatch_panics() {
        let _ = Tensor::from_vec(&[2, 2], vec![1.0]);
    }

    #[test]
    fn reshape_preserves_data() {
        let t = Tensor::vector(&[1.0, 2.0, 3.0, 4.0]);
        let m = t.reshape(&[2, 2]);
        assert_eq!(m.at(1, 1), 4.0);
    }

    #[test]
    fn randn_deterministic_by_seed() {
        let mut r1 = Xoshiro256::new(9);
        let mut r2 = Xoshiro256::new(9);
        let a = Tensor::randn(&[4, 4], &mut r1);
        let b = Tensor::randn(&[4, 4], &mut r2);
        assert_eq!(a, b);
    }
}
