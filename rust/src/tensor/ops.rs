//! Elementwise and reduction operations on [`Tensor`].
//!
//! Elementwise arithmetic routes through the chunked lane helpers
//! ([`super::lanes`]) — the same loops the shared kernels run, so there is
//! exactly one copy of each elementwise sweep in the crate (the PR 4
//! single-kernel invariant extended to elementwise arithmetic).
//! Reductions (`sum`, `dot`, `norm_sq`, …) stay sequential left-to-right:
//! chunking them would change the accumulation order and break the
//! bitwise oracles.

use super::{lanes, Tensor};

impl Tensor {
    /// Elementwise binary op with another tensor of identical shape.
    pub fn zip_with(&self, other: &Tensor, f: impl Fn(f64, f64) -> f64) -> Tensor {
        assert_eq!(self.dims(), other.dims(), "zip_with shape mismatch");
        let data = self
            .data()
            .iter()
            .zip(other.data())
            .map(|(&a, &b)| f(a, b))
            .collect();
        Tensor::from_vec(self.dims(), data)
    }

    /// Elementwise unary map.
    pub fn map(&self, f: impl Fn(f64) -> f64) -> Tensor {
        Tensor::from_vec(self.dims(), self.data().iter().map(|&x| f(x)).collect())
    }

    /// In-place elementwise map.
    pub fn map_inplace(&mut self, f: impl Fn(f64) -> f64) {
        for x in self.data_mut() {
            *x = f(*x);
        }
    }

    pub fn add(&self, o: &Tensor) -> Tensor {
        assert_eq!(self.dims(), o.dims(), "add shape mismatch");
        let mut out = vec![0.0; self.numel()];
        lanes::add_into(&mut out, self.data(), o.data());
        Tensor::from_vec(self.dims(), out)
    }

    pub fn sub(&self, o: &Tensor) -> Tensor {
        assert_eq!(self.dims(), o.dims(), "sub shape mismatch");
        let mut out = vec![0.0; self.numel()];
        lanes::sub_into(&mut out, self.data(), o.data());
        Tensor::from_vec(self.dims(), out)
    }

    pub fn mul(&self, o: &Tensor) -> Tensor {
        assert_eq!(self.dims(), o.dims(), "mul shape mismatch");
        let mut out = vec![0.0; self.numel()];
        lanes::mul_into(&mut out, self.data(), o.data());
        Tensor::from_vec(self.dims(), out)
    }

    pub fn scale(&self, s: f64) -> Tensor {
        let mut out = vec![0.0; self.numel()];
        lanes::scale_into(&mut out, self.data(), s);
        Tensor::from_vec(self.dims(), out)
    }

    /// `self += alpha * other` (AXPY), in place.
    pub fn axpy(&mut self, alpha: f64, other: &Tensor) {
        assert_eq!(self.dims(), other.dims(), "axpy shape mismatch");
        lanes::axpy(self.data_mut(), alpha, other.data());
    }

    /// Sum of all elements.
    pub fn sum(&self) -> f64 {
        self.data().iter().sum()
    }

    /// Mean of all elements (0 for empty).
    pub fn mean(&self) -> f64 {
        if self.numel() == 0 {
            0.0
        } else {
            self.sum() / self.numel() as f64
        }
    }

    /// Dot product with another tensor of identical shape.
    pub fn dot(&self, o: &Tensor) -> f64 {
        assert_eq!(self.dims(), o.dims(), "dot shape mismatch");
        self.data().iter().zip(o.data()).map(|(&a, &b)| a * b).sum()
    }

    /// Squared Frobenius/L2 norm.
    pub fn norm_sq(&self) -> f64 {
        self.data().iter().map(|&x| x * x).sum()
    }

    /// Frobenius/L2 norm.
    pub fn norm(&self) -> f64 {
        self.norm_sq().sqrt()
    }

    /// Max absolute element (0 for empty).
    pub fn max_abs(&self) -> f64 {
        self.data().iter().fold(0.0, |m, &x| m.max(x.abs()))
    }

    /// Max absolute difference against another tensor.
    pub fn max_abs_diff(&self, o: &Tensor) -> f64 {
        assert_eq!(self.dims(), o.dims());
        self.data()
            .iter()
            .zip(o.data())
            .fold(0.0, |m, (&a, &b)| m.max((a - b).abs()))
    }

    /// Relative L2 error `|self - o| / max(|o|, eps)`.
    pub fn rel_l2_error(&self, o: &Tensor) -> f64 {
        let diff = self.sub(o).norm();
        diff / o.norm().max(1e-30)
    }

    /// True when all elements are finite.
    pub fn all_finite(&self) -> bool {
        is_finite(self.data())
    }
}

/// True when every element of `data` is finite (no NaN / ±Inf).
pub fn is_finite(data: &[f64]) -> bool {
    first_non_finite(data).is_none()
}

/// Flat index of the first non-finite element, if any.
pub fn first_non_finite(data: &[f64]) -> Option<usize> {
    data.iter().position(|x| !x.is_finite())
}

/// f32 twin of [`first_non_finite`] for the serving front door, which
/// validates request points *before* the f32→f64 cast (the cast preserves
/// finiteness exactly, so the two checks agree).
pub fn first_non_finite_f32(data: &[f32]) -> Option<usize> {
    data.iter().position(|x| !x.is_finite())
}

/// Validate a `[batch, n]` evaluation input against a model input
/// dimension: 2-D shape, matching width, and all-finite values.
///
/// This is the **shared rejection gate** every engine's `validate_input`
/// delegates to, so the error text for a given bad input is identical
/// across DOF / Hessian / jet engines (asserted by the poisoned-input
/// family in `rust/tests/cross_engine_fuzz.rs`) — a router retrying a
/// rejected request on another engine learns nothing new.
pub fn validate_batch_input(expect_width: usize, x: &Tensor) -> Result<(), String> {
    let dims = x.dims();
    if dims.len() != 2 {
        return Err(format!("input must be [batch, n], got {dims:?}"));
    }
    if dims[1] != expect_width {
        return Err(format!(
            "input width {} does not match model input dimension {expect_width}",
            dims[1]
        ));
    }
    if let Some(i) = first_non_finite(x.data()) {
        let (r, c) = (i / expect_width.max(1), i % expect_width.max(1));
        return Err(format!(
            "non-finite input at row {r}, column {c}: {}",
            x.data()[i]
        ));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn elementwise_ops() {
        let a = Tensor::vector(&[1.0, 2.0, 3.0]);
        let b = Tensor::vector(&[4.0, 5.0, 6.0]);
        assert_eq!(a.add(&b).data(), &[5.0, 7.0, 9.0]);
        assert_eq!(b.sub(&a).data(), &[3.0, 3.0, 3.0]);
        assert_eq!(a.mul(&b).data(), &[4.0, 10.0, 18.0]);
        assert_eq!(a.scale(2.0).data(), &[2.0, 4.0, 6.0]);
        assert_eq!(a.dot(&b), 32.0);
    }

    #[test]
    fn axpy_inplace() {
        let mut a = Tensor::vector(&[1.0, 1.0]);
        let b = Tensor::vector(&[2.0, 3.0]);
        a.axpy(0.5, &b);
        assert_eq!(a.data(), &[2.0, 2.5]);
    }

    #[test]
    fn reductions() {
        let a = Tensor::vector(&[3.0, -4.0]);
        assert_eq!(a.sum(), -1.0);
        assert_eq!(a.mean(), -0.5);
        assert_eq!(a.norm(), 5.0);
        assert_eq!(a.max_abs(), 4.0);
    }

    #[test]
    fn error_metrics() {
        let a = Tensor::vector(&[1.0, 2.0]);
        let b = Tensor::vector(&[1.0, 2.5]);
        assert!((a.max_abs_diff(&b) - 0.5).abs() < 1e-12);
        assert!(a.rel_l2_error(&a) < 1e-15);
        assert!(a.all_finite());
        let nan = Tensor::vector(&[f64::NAN]);
        assert!(!nan.all_finite());
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        let a = Tensor::vector(&[1.0]);
        let b = Tensor::vector(&[1.0, 2.0]);
        let _ = a.add(&b);
    }

    #[test]
    fn non_finite_position_reported() {
        assert_eq!(first_non_finite(&[1.0, 2.0]), None);
        assert_eq!(first_non_finite(&[1.0, f64::NAN, f64::INFINITY]), Some(1));
        assert_eq!(first_non_finite_f32(&[0.5, f32::NEG_INFINITY]), Some(1));
        assert!(is_finite(&[0.0, -1.0]));
    }

    #[test]
    fn batch_input_validation_messages() {
        let ok = Tensor::from_vec(&[2, 3], vec![0.0; 6]);
        assert!(validate_batch_input(3, &ok).is_ok());
        let e = validate_batch_input(4, &ok).unwrap_err();
        assert!(e.contains("width 3"), "{e}");
        let flat = Tensor::vector(&[1.0, 2.0]);
        assert!(validate_batch_input(2, &flat).unwrap_err().contains("[batch, n]"));
        let mut bad = Tensor::from_vec(&[2, 3], vec![0.0; 6]);
        bad.data_mut()[4] = f64::NAN;
        let e = validate_batch_input(3, &bad).unwrap_err();
        assert!(e.contains("row 1, column 1"), "{e}");
    }
}
