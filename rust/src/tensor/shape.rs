//! Shape metadata for dense tensors.

/// Immutable list of dimension sizes with cached element count.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct Shape {
    dims: Vec<usize>,
    numel: usize,
}

impl Shape {
    /// New shape from dimension sizes. Zero-sized dims are allowed.
    pub fn new(dims: &[usize]) -> Self {
        let numel = dims.iter().product();
        Self {
            dims: dims.to_vec(),
            numel,
        }
    }

    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    pub fn rank(&self) -> usize {
        self.dims.len()
    }

    pub fn numel(&self) -> usize {
        self.numel
    }

    /// Row-major strides.
    pub fn strides(&self) -> Vec<usize> {
        let mut strides = vec![1usize; self.dims.len()];
        for i in (0..self.dims.len().saturating_sub(1)).rev() {
            strides[i] = strides[i + 1] * self.dims[i + 1];
        }
        strides
    }

    /// Linear index of a multi-index; panics if out of bounds in debug.
    pub fn offset(&self, idx: &[usize]) -> usize {
        debug_assert_eq!(idx.len(), self.dims.len());
        let strides = self.strides();
        idx.iter()
            .zip(strides.iter())
            .map(|(&i, &s)| {
                debug_assert!(i < self.dims[idx.len() - strides.len() + 0].max(usize::MAX));
                i * s
            })
            .sum()
    }
}

impl std::fmt::Display for Shape {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "[{}]",
            self.dims
                .iter()
                .map(|d| d.to_string())
                .collect::<Vec<_>>()
                .join(", ")
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn numel_and_strides() {
        let s = Shape::new(&[2, 3, 4]);
        assert_eq!(s.numel(), 24);
        assert_eq!(s.strides(), vec![12, 4, 1]);
        assert_eq!(s.offset(&[1, 2, 3]), 12 + 8 + 3);
    }

    #[test]
    fn scalar_shape() {
        let s = Shape::new(&[]);
        assert_eq!(s.numel(), 1);
        assert_eq!(s.rank(), 0);
    }

    #[test]
    fn display() {
        assert_eq!(Shape::new(&[2, 3]).to_string(), "[2, 3]");
    }
}
