//! Training substrate: optimizers and collocation samplers for the PINN
//! workloads that exercise DOF end-to-end.

pub mod optim;
pub mod sampler;

pub use optim::{Adam, AdamConfig, Sgd};
pub use sampler::{BoundarySampler, BoxSampler};
