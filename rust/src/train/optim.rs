//! First-order optimizers over flat parameter vectors.

/// Adam hyperparameters.
#[derive(Debug, Clone, Copy)]
pub struct AdamConfig {
    pub lr: f64,
    pub beta1: f64,
    pub beta2: f64,
    pub eps: f64,
    /// Decoupled weight decay (AdamW style); 0 disables.
    pub weight_decay: f64,
}

impl Default for AdamConfig {
    fn default() -> Self {
        Self {
            lr: 1e-3,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            weight_decay: 0.0,
        }
    }
}

/// Adam with bias correction.
#[derive(Debug, Clone)]
pub struct Adam {
    pub cfg: AdamConfig,
    m: Vec<f64>,
    v: Vec<f64>,
    t: u64,
}

impl Adam {
    pub fn new(n_params: usize, cfg: AdamConfig) -> Self {
        Self {
            cfg,
            m: vec![0.0; n_params],
            v: vec![0.0; n_params],
            t: 0,
        }
    }

    /// One update step: `params ← params − lr·m̂/(√v̂ + ε)`.
    pub fn step(&mut self, params: &mut [f64], grads: &[f64]) {
        assert_eq!(params.len(), self.m.len(), "param count changed");
        assert_eq!(grads.len(), self.m.len(), "grad count mismatch");
        self.t += 1;
        let c = &self.cfg;
        let bc1 = 1.0 - c.beta1.powi(self.t as i32);
        let bc2 = 1.0 - c.beta2.powi(self.t as i32);
        for i in 0..params.len() {
            let g = grads[i];
            self.m[i] = c.beta1 * self.m[i] + (1.0 - c.beta1) * g;
            self.v[i] = c.beta2 * self.v[i] + (1.0 - c.beta2) * g * g;
            let mhat = self.m[i] / bc1;
            let vhat = self.v[i] / bc2;
            let mut upd = mhat / (vhat.sqrt() + c.eps);
            if c.weight_decay > 0.0 {
                upd += c.weight_decay * params[i];
            }
            params[i] -= c.lr * upd;
        }
    }

    pub fn steps_taken(&self) -> u64 {
        self.t
    }
}

/// Plain SGD with optional momentum.
#[derive(Debug, Clone)]
pub struct Sgd {
    pub lr: f64,
    pub momentum: f64,
    velocity: Vec<f64>,
}

impl Sgd {
    pub fn new(n_params: usize, lr: f64, momentum: f64) -> Self {
        Self {
            lr,
            momentum,
            velocity: vec![0.0; n_params],
        }
    }

    pub fn step(&mut self, params: &mut [f64], grads: &[f64]) {
        assert_eq!(params.len(), self.velocity.len());
        for i in 0..params.len() {
            self.velocity[i] = self.momentum * self.velocity[i] + grads[i];
            params[i] -= self.lr * self.velocity[i];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Adam minimizes a convex quadratic.
    #[test]
    fn adam_minimizes_quadratic() {
        let mut params = vec![5.0, -3.0, 2.0];
        let target = [1.0, 2.0, -1.0];
        let mut opt = Adam::new(3, AdamConfig { lr: 0.05, ..Default::default() });
        for _ in 0..2000 {
            let grads: Vec<f64> = params
                .iter()
                .zip(&target)
                .map(|(&p, &t)| 2.0 * (p - t))
                .collect();
            opt.step(&mut params, &grads);
        }
        for (p, t) in params.iter().zip(&target) {
            assert!((p - t).abs() < 1e-3, "{p} vs {t}");
        }
    }

    #[test]
    fn sgd_with_momentum_converges() {
        let mut params = vec![4.0];
        let mut opt = Sgd::new(1, 0.05, 0.9);
        for _ in 0..500 {
            let g = vec![2.0 * params[0]];
            opt.step(&mut params, &g);
        }
        assert!(params[0].abs() < 1e-6);
    }

    #[test]
    fn adam_bias_correction_first_step() {
        // After one step with g = 1, update ≈ lr·1 regardless of betas.
        let mut params = vec![0.0];
        let mut opt = Adam::new(1, AdamConfig { lr: 0.1, ..Default::default() });
        opt.step(&mut params, &[1.0]);
        assert!((params[0] + 0.1).abs() < 1e-6, "{}", params[0]);
    }

    #[test]
    #[should_panic]
    fn grad_len_mismatch_panics() {
        let mut opt = Adam::new(2, AdamConfig::default());
        let mut p = vec![0.0, 0.0];
        opt.step(&mut p, &[1.0]);
    }
}
