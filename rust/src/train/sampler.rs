//! Collocation-point samplers for PINN training: interior points in an
//! axis-aligned box and boundary/initial-condition points on its faces.

use crate::tensor::Tensor;
use crate::util::Xoshiro256;

/// Uniform sampler over the box `Π_i [lo_i, hi_i]`.
#[derive(Debug, Clone)]
pub struct BoxSampler {
    pub lo: Vec<f64>,
    pub hi: Vec<f64>,
}

impl BoxSampler {
    pub fn new(lo: Vec<f64>, hi: Vec<f64>) -> Self {
        assert_eq!(lo.len(), hi.len());
        for (l, h) in lo.iter().zip(&hi) {
            assert!(l < h, "degenerate box [{l}, {h}]");
        }
        Self { lo, hi }
    }

    /// Unit cube `[0,1]^n`.
    pub fn unit(n: usize) -> Self {
        Self::new(vec![0.0; n], vec![1.0; n])
    }

    pub fn dim(&self) -> usize {
        self.lo.len()
    }

    /// Sample `count` interior points, `[count, dim]`.
    pub fn sample(&self, count: usize, rng: &mut Xoshiro256) -> Tensor {
        let d = self.dim();
        let mut t = Tensor::zeros(&[count, d]);
        for b in 0..count {
            let row = t.row_mut(b);
            for i in 0..d {
                row[i] = rng.uniform(self.lo[i], self.hi[i]);
            }
        }
        t
    }
}

/// Sampler on the faces of a box. Each sample picks a face uniformly among
/// the selected ones and samples the remaining coordinates uniformly.
#[derive(Debug, Clone)]
pub struct BoundarySampler {
    pub box_: BoxSampler,
    /// Faces as `(axis, at_hi)`; e.g. `(2, false)` = the `x_2 = lo_2` face.
    pub faces: Vec<(usize, bool)>,
}

impl BoundarySampler {
    /// All `2·dim` faces.
    pub fn all_faces(box_: BoxSampler) -> Self {
        let d = box_.dim();
        let faces = (0..d).flat_map(|i| [(i, false), (i, true)]).collect();
        Self { box_, faces }
    }

    /// Only selected faces (e.g. the `t = 0` slab for initial conditions).
    pub fn faces(box_: BoxSampler, faces: Vec<(usize, bool)>) -> Self {
        for &(axis, _) in &faces {
            assert!(axis < box_.dim());
        }
        Self { box_, faces }
    }

    pub fn sample(&self, count: usize, rng: &mut Xoshiro256) -> Tensor {
        let mut t = self.box_.sample(count, rng);
        for b in 0..count {
            let &(axis, at_hi) = rng.choose(&self.faces);
            let v = if at_hi { self.box_.hi[axis] } else { self.box_.lo[axis] };
            t.row_mut(b)[axis] = v;
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn interior_points_in_box() {
        let s = BoxSampler::new(vec![-1.0, 0.0], vec![1.0, 2.0]);
        let mut rng = Xoshiro256::new(1);
        let pts = s.sample(500, &mut rng);
        for b in 0..500 {
            let r = pts.row(b);
            assert!((-1.0..=1.0).contains(&r[0]));
            assert!((0.0..=2.0).contains(&r[1]));
        }
    }

    #[test]
    fn boundary_points_on_faces() {
        let s = BoundarySampler::all_faces(BoxSampler::unit(3));
        let mut rng = Xoshiro256::new(2);
        let pts = s.sample(300, &mut rng);
        for b in 0..300 {
            let r = pts.row(b);
            let on_face = r.iter().any(|&v| v == 0.0 || v == 1.0);
            assert!(on_face, "point {r:?} not on any face");
        }
    }

    #[test]
    fn initial_condition_face_only() {
        // t = x_2 = 0 slab.
        let s = BoundarySampler::faces(BoxSampler::unit(3), vec![(2, false)]);
        let mut rng = Xoshiro256::new(3);
        let pts = s.sample(100, &mut rng);
        for b in 0..100 {
            assert_eq!(pts.row(b)[2], 0.0);
        }
    }

    #[test]
    #[should_panic]
    fn degenerate_box_panics() {
        let _ = BoxSampler::new(vec![1.0], vec![1.0]);
    }
}
