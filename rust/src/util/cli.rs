//! Minimal command-line argument parser.
//!
//! The environment has no `clap`; this module provides the small subset the
//! `dof` binary needs: subcommands, `--flag`, `--key value` / `--key=value`
//! options with typed accessors and defaults, and positional arguments.

use std::collections::BTreeMap;
use std::fmt;

/// Parsed command line: a subcommand, options, flags, and positionals.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// First non-option token (subcommand), if any.
    pub command: Option<String>,
    /// `--key value` or `--key=value` pairs.
    pub options: BTreeMap<String, String>,
    /// Bare `--flag` tokens.
    pub flags: Vec<String>,
    /// Remaining positional arguments after the subcommand.
    pub positionals: Vec<String>,
}

/// Error produced when an option fails to parse into its typed form.
#[derive(Debug)]
pub struct ParseError {
    pub key: String,
    pub value: String,
    pub ty: &'static str,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "option --{} has value {:?} which is not a valid {}",
            self.key, self.value, self.ty
        )
    }
}

impl std::error::Error for ParseError {}

impl Args {
    /// Parse from an iterator of argument tokens (excluding `argv[0]`).
    ///
    /// Rules: a token starting with `--` is a flag; if the *next* token does
    /// not start with `--`, it is consumed as that flag's value (so boolean
    /// flags should come last or be followed by other `--` tokens;
    /// `--key=value` is unambiguous). The first bare token becomes the
    /// subcommand; later bare tokens are positionals.
    pub fn parse<I, S>(tokens: I) -> Self
    where
        I: IntoIterator<Item = S>,
        S: Into<String>,
    {
        let toks: Vec<String> = tokens.into_iter().map(Into::into).collect();
        let mut args = Args::default();
        let mut i = 0;
        while i < toks.len() {
            let t = &toks[i];
            if let Some(stripped) = t.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if i + 1 < toks.len() && !toks[i + 1].starts_with("--") {
                    args.options
                        .insert(stripped.to_string(), toks[i + 1].clone());
                    i += 1;
                } else {
                    args.flags.push(stripped.to_string());
                }
            } else if args.command.is_none() {
                args.command = Some(t.clone());
            } else {
                args.positionals.push(t.clone());
            }
            i += 1;
        }
        args
    }

    /// Parse from the process environment.
    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    /// Is the given boolean flag present (either `--name` or `--name true`)?
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
            || self
                .options
                .get(name)
                .map(|v| v == "true" || v == "1")
                .unwrap_or(false)
    }

    /// Raw string option.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    /// String option with default.
    pub fn get_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    /// Typed option with default; returns Err on malformed value.
    pub fn get_parsed_or<T: std::str::FromStr>(
        &self,
        name: &str,
        default: T,
    ) -> Result<T, ParseError> {
        match self.options.get(name) {
            None => Ok(default),
            Some(v) => v.parse::<T>().map_err(|_| ParseError {
                key: name.to_string(),
                value: v.clone(),
                ty: std::any::type_name::<T>(),
            }),
        }
    }

    /// usize option with default (panics with a readable message on error —
    /// appropriate for CLI entry points).
    pub fn usize_or(&self, name: &str, default: usize) -> usize {
        self.get_parsed_or(name, default).unwrap_or_else(|e| panic!("{e}"))
    }

    /// u64 option with default.
    pub fn u64_or(&self, name: &str, default: u64) -> u64 {
        self.get_parsed_or(name, default).unwrap_or_else(|e| panic!("{e}"))
    }

    /// f64 option with default.
    pub fn f64_or(&self, name: &str, default: f64) -> f64 {
        self.get_parsed_or(name, default).unwrap_or_else(|e| panic!("{e}"))
    }

    /// Validated thread-count option (`--threads`): `Ok(None)` when absent,
    /// a clear error for `0`, negative, or non-numeric values.
    pub fn thread_count(&self, name: &str) -> Result<Option<usize>, String> {
        match self.get(name) {
            None => Ok(None),
            Some(v) => parse_thread_count(v)
                .map(Some)
                .map_err(|e| format!("--{name}: {e}")),
        }
    }

    /// Comma-separated usize list with default (e.g. `--threads 1,2,4,8`);
    /// panics with a readable message on malformed entries.
    pub fn usize_list_or(&self, name: &str, default: &[usize]) -> Vec<usize> {
        match self.get(name) {
            None => default.to_vec(),
            Some(v) => v
                .split(',')
                .filter(|s| !s.trim().is_empty())
                .map(|s| {
                    s.trim().parse::<usize>().unwrap_or_else(|_| {
                        panic!(
                            "option --{name} has value {v:?} which is not a \
                             comma-separated usize list"
                        )
                    })
                })
                .collect(),
        }
    }
}

/// Parse a worker thread count: a positive integer. Shared by the
/// `--threads` CLI option and the `DOF_THREADS` environment variable so
/// both reject `0` and non-numeric values with the same clear message
/// instead of panicking or silently falling back.
pub fn parse_thread_count(raw: &str) -> Result<usize, String> {
    match raw.trim().parse::<usize>() {
        Ok(0) => Err(format!(
            "thread count must be a positive integer (≥ 1), got {raw:?}"
        )),
        Ok(t) => Ok(t),
        Err(_) => Err(format!(
            "thread count must be a positive integer (≥ 1), got {raw:?}"
        )),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn thread_count_accepts_positive_integers() {
        assert_eq!(parse_thread_count("1"), Ok(1));
        assert_eq!(parse_thread_count("8"), Ok(8));
        assert_eq!(parse_thread_count(" 4 "), Ok(4));
    }

    #[test]
    fn thread_count_rejects_zero_and_garbage() {
        for bad in ["0", "-2", "eight", "", "4.5", "1e2"] {
            let err = parse_thread_count(bad).unwrap_err();
            assert!(
                err.contains("positive integer") && err.contains(bad.trim()),
                "error for {bad:?} should name the value: {err}"
            );
        }
    }

    #[test]
    fn thread_count_option_accessor() {
        let a = Args::parse(vec!["bench", "--threads", "6"]);
        assert_eq!(a.thread_count("threads"), Ok(Some(6)));
        let missing = Args::parse(vec!["bench"]);
        assert_eq!(missing.thread_count("threads"), Ok(None));
        let bad = Args::parse(vec!["bench", "--threads", "zero"]);
        let err = bad.thread_count("threads").unwrap_err();
        assert!(err.starts_with("--threads:"), "{err}");
    }

    #[test]
    fn parses_subcommand_options_flags() {
        let a = Args::parse(vec![
            "bench", "table1", "--reps", "20", "--operator=elliptic", "--verbose",
        ]);
        assert_eq!(a.command.as_deref(), Some("bench"));
        assert_eq!(a.positionals, vec!["table1"]);
        assert_eq!(a.get("reps"), Some("20"));
        assert_eq!(a.get("operator"), Some("elliptic"));
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn typed_accessors() {
        let a = Args::parse(vec!["run", "--n", "64", "--lr", "0.001"]);
        assert_eq!(a.usize_or("n", 1), 64);
        assert_eq!(a.usize_or("missing", 7), 7);
        assert!((a.f64_or("lr", 0.1) - 0.001).abs() < 1e-12);
    }

    #[test]
    fn usize_lists() {
        let a = Args::parse(vec!["bench", "--threads", "1,2,4,8", "--batches=64,256"]);
        assert_eq!(a.usize_list_or("threads", &[1]), vec![1, 2, 4, 8]);
        assert_eq!(a.usize_list_or("batches", &[8]), vec![64, 256]);
        assert_eq!(a.usize_list_or("missing", &[3, 5]), vec![3, 5]);
    }

    #[test]
    fn malformed_value_is_error() {
        let a = Args::parse(vec!["run", "--n", "sixty"]);
        assert!(a.get_parsed_or::<usize>("n", 1).is_err());
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = Args::parse(vec!["x", "--fast", "--n", "3"]);
        assert!(a.flag("fast"));
        assert_eq!(a.usize_or("n", 0), 3);
    }

    #[test]
    fn empty_input() {
        let a = Args::parse(Vec::<String>::new());
        assert!(a.command.is_none());
        assert!(a.options.is_empty());
    }
}
