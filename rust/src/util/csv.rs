//! Tiny CSV writer used by benches to emit plottable series.

use std::fmt::Write as _;
use std::fs;
use std::io;
use std::path::Path;

/// In-memory CSV table with a fixed header.
#[derive(Debug, Clone)]
pub struct CsvTable {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl CsvTable {
    /// Create a table with the given column names.
    pub fn new<S: Into<String>>(header: Vec<S>) -> Self {
        Self {
            header: header.into_iter().map(Into::into).collect(),
            rows: Vec::new(),
        }
    }

    /// Number of columns.
    pub fn width(&self) -> usize {
        self.header.len()
    }

    /// Number of data rows.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Push a row; panics if the arity does not match the header.
    pub fn push<S: Into<String>>(&mut self, row: Vec<S>) {
        let row: Vec<String> = row.into_iter().map(Into::into).collect();
        assert_eq!(
            row.len(),
            self.header.len(),
            "CSV row arity {} != header arity {}",
            row.len(),
            self.header.len()
        );
        self.rows.push(row);
    }

    /// Render to a CSV string (RFC-4180-ish quoting: fields containing
    /// comma, quote or newline are quoted; quotes are doubled).
    pub fn render(&self) -> String {
        let mut out = String::new();
        let esc = |f: &str| -> String {
            if f.contains(',') || f.contains('"') || f.contains('\n') {
                format!("\"{}\"", f.replace('"', "\"\""))
            } else {
                f.to_string()
            }
        };
        let _ = writeln!(
            out,
            "{}",
            self.header.iter().map(|h| esc(h)).collect::<Vec<_>>().join(",")
        );
        for row in &self.rows {
            let _ = writeln!(
                out,
                "{}",
                row.iter().map(|f| esc(f)).collect::<Vec<_>>().join(",")
            );
        }
        out
    }

    /// Write to a file, creating parent directories.
    pub fn write_to<P: AsRef<Path>>(&self, path: P) -> io::Result<()> {
        if let Some(parent) = path.as_ref().parent() {
            fs::create_dir_all(parent)?;
        }
        fs::write(path, self.render())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_header_and_rows() {
        let mut t = CsvTable::new(vec!["a", "b"]);
        t.push(vec!["1", "2"]);
        t.push(vec!["x,y", "q\"r"]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines[0], "a,b");
        assert_eq!(lines[1], "1,2");
        assert_eq!(lines[2], "\"x,y\",\"q\"\"r\"");
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        let mut t = CsvTable::new(vec!["a", "b"]);
        t.push(vec!["only-one"]);
    }

    #[test]
    fn roundtrip_file() {
        let mut t = CsvTable::new(vec!["n", "flops"]);
        t.push(vec!["64", "123456"]);
        let p = std::env::temp_dir().join("dof_csv_test.csv");
        t.write_to(&p).unwrap();
        let s = std::fs::read_to_string(&p).unwrap();
        assert!(s.starts_with("n,flops"));
        let _ = std::fs::remove_file(&p);
    }
}
