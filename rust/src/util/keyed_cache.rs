//! The one keyed double-checked cache behind every compile-once subsystem.
//!
//! `PlanCache`, `JetCache`, and `HessianPlanCache` were three verbatim
//! copies of the same mechanism (lock → check → compile outside the lock →
//! recheck → first-insert-wins, oldest-entry eviction, hit/miss counters) —
//! the same disease the op kernels had before PR 4, cured the same way:
//! one generic definition, thin consumers. The three caches are now
//! wrappers over [`KeyedCache`] that only contribute their key derivation
//! and compile closure; `rust/tests/cache_soundness.rs` exercises the
//! shared mechanism through all three.
//!
//! ## Contract
//!
//! * **Double-checked compile** — the value is built *outside* the lock
//!   (compiles are milliseconds; holding the lock would serialize every
//!   concurrent consumer on one compile). A racing build of the same key
//!   keeps the first inserted value; the loser's work is dropped and the
//!   loser returns the winner's `Arc` (so pointer-identity assertions hold
//!   across racing callers).
//! * **Bounded** — insertion past `cap` evicts the oldest entry (plain FIFO
//!   by insert order; the store is a small associative list, a handful of
//!   model/operator pairs in any realistic process).
//! * **Counters** — `hits` counts lookups served by an existing entry
//!   (including second-check hits after a lost race), `misses` counts
//!   inserts; `entries` is current occupancy.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Hit/miss counters plus current occupancy, shared by every consumer
/// (`PlanCacheStats`, `JetCacheStats`, and `HessianCacheStats` are aliases
/// of this type).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CacheStats {
    /// Lookups served by an already-present value.
    pub hits: u64,
    /// Lookups that built and inserted.
    pub misses: u64,
    /// Values currently retained.
    pub entries: usize,
}

/// A bounded, keyed, double-checked cache of `Arc<V>` (see module docs).
pub struct KeyedCache<K, V> {
    cap: usize,
    entries: Mutex<Vec<(K, Arc<V>)>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl<K: PartialEq + Clone, V> KeyedCache<K, V> {
    /// An empty cache retaining at most `cap` values.
    pub const fn new(cap: usize) -> Self {
        Self {
            cap,
            entries: Mutex::new(Vec::new()),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    /// Fetch the value for `key`, building it with `build` on first use.
    /// `build` runs outside the lock; on a racing build of the same key the
    /// first inserted value wins and every caller gets that same `Arc`.
    pub fn get_or_insert_with(&self, key: K, build: impl FnOnce() -> V) -> Arc<V> {
        {
            let entries = self.entries.lock().expect("keyed cache poisoned");
            if let Some((_, v)) = entries.iter().find(|(k, _)| *k == key) {
                self.hits.fetch_add(1, Ordering::Relaxed);
                return Arc::clone(v);
            }
        }
        let value = Arc::new(build());
        let mut entries = self.entries.lock().expect("keyed cache poisoned");
        if let Some((_, v)) = entries.iter().find(|(k, _)| *k == key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Arc::clone(v);
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        if entries.len() >= self.cap {
            entries.remove(0);
        }
        entries.push((key, Arc::clone(&value)));
        value
    }

    /// Is `key` currently retained? (No counter side effects.)
    pub fn contains(&self, key: &K) -> bool {
        self.entries
            .lock()
            .expect("keyed cache poisoned")
            .iter()
            .any(|(k, _)| k == key)
    }

    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            entries: self.entries.lock().expect("keyed cache poisoned").len(),
        }
    }

    /// Drop every retained value (counters are kept).
    pub fn clear(&self) {
        self.entries.lock().expect("keyed cache poisoned").clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn second_lookup_hits_by_pointer_identity() {
        let cache: KeyedCache<u64, u64> = KeyedCache::new(4);
        let a = cache.get_or_insert_with(1, || 10);
        let b = cache.get_or_insert_with(1, || 99);
        assert!(Arc::ptr_eq(&a, &b), "same key must reuse the value");
        assert_eq!(*b, 10, "losing builder's value must be discarded");
        let st = cache.stats();
        assert_eq!((st.hits, st.misses, st.entries), (1, 1, 1));
    }

    #[test]
    fn evicts_oldest_past_cap() {
        let cache: KeyedCache<u64, u64> = KeyedCache::new(2);
        let v1 = cache.get_or_insert_with(1, || 1);
        let _v2 = cache.get_or_insert_with(2, || 2);
        let _v3 = cache.get_or_insert_with(3, || 3); // evicts key 1
        assert_eq!(cache.stats().entries, 2);
        assert!(!cache.contains(&1), "oldest entry evicted");
        assert!(cache.contains(&2) && cache.contains(&3));
        // Re-inserting the evicted key is a miss with a fresh value.
        let v1b = cache.get_or_insert_with(1, || 4);
        assert!(!Arc::ptr_eq(&v1, &v1b));
        assert_eq!(*v1b, 4);
        assert_eq!(cache.stats().misses, 4);
    }

    #[test]
    fn clear_keeps_counters() {
        let cache: KeyedCache<u64, u64> = KeyedCache::new(4);
        let _ = cache.get_or_insert_with(1, || 1);
        let _ = cache.get_or_insert_with(1, || 1);
        cache.clear();
        let st = cache.stats();
        assert_eq!(st.entries, 0);
        assert_eq!((st.hits, st.misses), (1, 1));
    }

    #[test]
    fn concurrent_same_key_returns_one_arc() {
        let cache: Arc<KeyedCache<u64, Vec<u8>>> = Arc::new(KeyedCache::new(4));
        let arcs: Vec<_> = (0..8)
            .map(|_| {
                let cache = Arc::clone(&cache);
                std::thread::spawn(move || cache.get_or_insert_with(7, || vec![1, 2, 3]))
            })
            .collect();
        let got: Vec<_> = arcs.into_iter().map(|j| j.join().unwrap()).collect();
        for v in &got[1..] {
            assert!(Arc::ptr_eq(&got[0], v), "racing builds must converge");
        }
        assert_eq!(cache.stats().entries, 1);
        assert_eq!(cache.stats().misses, 1);
    }
}
