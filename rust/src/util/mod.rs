//! General-purpose substrates: deterministic RNG, CLI parsing, CSV output,
//! and statistics. These replace external crates (`rand`, `clap`, `csv`,
//! `criterion`'s stats) that are unavailable in the offline build.

pub mod cli;
pub mod csv;
pub mod keyed_cache;
pub mod prng;
pub mod stats;

pub use cli::{parse_thread_count, Args};
pub use csv::CsvTable;
pub use keyed_cache::{CacheStats, KeyedCache};
pub use prng::{SplitMix64, Xoshiro256};
pub use stats::{fmt_bytes, fmt_duration, LatencyHistogram, Summary};

/// Extract a human-readable message from a `catch_unwind` payload.
///
/// Panic payloads are `Box<dyn Any>`; in practice they are a `String`
/// (from `panic!("…{x}")`) or a `&'static str` (from `panic!("literal")`).
/// Anything else is reported as opaque rather than dropped — fault reports
/// at the serving boundary must never lose the cause entirely.
pub fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    match payload.downcast::<String>() {
        Ok(s) => *s,
        Err(payload) => match payload.downcast::<&'static str>() {
            Ok(s) => (*s).to_string(),
            Err(_) => "opaque panic payload".to_string(),
        },
    }
}
