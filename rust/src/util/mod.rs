//! General-purpose substrates: deterministic RNG, CLI parsing, CSV output,
//! and statistics. These replace external crates (`rand`, `clap`, `csv`,
//! `criterion`'s stats) that are unavailable in the offline build.

pub mod cli;
pub mod csv;
pub mod keyed_cache;
pub mod prng;
pub mod stats;

pub use cli::{parse_thread_count, Args};
pub use csv::CsvTable;
pub use keyed_cache::{CacheStats, KeyedCache};
pub use prng::{SplitMix64, Xoshiro256};
pub use stats::{fmt_bytes, fmt_duration, LatencyHistogram, Summary};
