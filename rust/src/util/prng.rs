//! Deterministic pseudo-random number generation.
//!
//! The repository deliberately avoids external RNG crates so that every
//! experiment is reproducible from a single `u64` seed across platforms.
//! Two generators are provided:
//!
//! * [`SplitMix64`] — tiny, used for seeding and hashing-style use.
//! * [`Xoshiro256`] — `xoshiro256**`, the workhorse generator used by the
//!   tensor fills, samplers, and the property-testing substrate.

/// SplitMix64: a 64-bit mixer suitable for seeding other generators.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create a generator from a raw seed.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// `xoshiro256**` — fast, high-quality, 256-bit state.
#[derive(Clone, Debug)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Seed via SplitMix64 as recommended by the xoshiro authors.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53 bits of mantissa.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Standard normal via Box–Muller (cached second value is *not* kept so
    /// that the stream position is a pure function of call count).
    pub fn normal(&mut self) -> f64 {
        // Rejection-free Box–Muller; u1 in (0,1].
        let u1 = 1.0 - self.next_f64();
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Uniform integer in `[0, n)`. `n` must be > 0.
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style widening multiply rejection is overkill here; the bias
        // of modulo on 64 bits with n << 2^64 is negligible for our uses, but
        // we keep the simple rejection loop for exactness.
        let n = n as u64;
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return (v % n) as usize;
            }
        }
    }

    /// Random boolean with probability `p` of being true.
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Choose one element uniformly.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Split off an independent generator (seeded from this stream).
    pub fn split(&mut self) -> Self {
        Self::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_is_deterministic() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn xoshiro_known_seed_differs_across_seeds() {
        let mut a = Xoshiro256::new(1);
        let mut b = Xoshiro256::new(2);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(va, vb);
    }

    #[test]
    fn uniform_range() {
        let mut r = Xoshiro256::new(7);
        for _ in 0..10_000 {
            let x = r.uniform(-3.0, 5.0);
            assert!((-3.0..5.0).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Xoshiro256::new(11);
        let n = 200_000;
        let mut sum = 0.0;
        let mut sumsq = 0.0;
        for _ in 0..n {
            let x = r.normal();
            sum += x;
            sumsq += x * x;
        }
        let mean = sum / n as f64;
        let var = sumsq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Xoshiro256::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let k = r.below(10);
            assert!(k < 10);
            seen[k] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256::new(5);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
