//! Small statistics helpers shared by the bench harness and metrics.

/// Summary statistics over a sample of f64 measurements.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub median: f64,
    pub p95: f64,
}

impl Summary {
    /// Compute a summary; returns a zeroed summary for an empty slice.
    pub fn of(xs: &[f64]) -> Summary {
        if xs.is_empty() {
            return Summary {
                n: 0,
                mean: 0.0,
                std: 0.0,
                min: 0.0,
                max: 0.0,
                median: 0.0,
                p95: 0.0,
            };
        }
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / (n.max(2) - 1) as f64;
        // Total order so a NaN measurement (e.g. a poisoned timing sample)
        // sorts deterministically instead of aborting telemetry mid-incident.
        let mut sorted = xs.to_vec();
        sorted.sort_by(f64::total_cmp);
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            median: percentile_sorted(&sorted, 0.50),
            p95: percentile_sorted(&sorted, 0.95),
        }
    }
}

/// Linear-interpolated percentile of a pre-sorted slice, `q` in [0,1].
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = pos - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Fixed-bucket latency histogram (log-spaced), used by coordinator metrics.
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    /// Bucket upper bounds in seconds (log spaced from 1us to ~100s).
    bounds: Vec<f64>,
    counts: Vec<u64>,
    total: u64,
    sum: f64,
    /// Non-finite samples rejected at [`Self::record`] (exact count).
    dropped_samples: u64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        // 1us .. 100s, 4 buckets per decade.
        let mut bounds = Vec::new();
        let mut b = 1e-6;
        while b <= 100.0 {
            for m in [1.0, 1.78, 3.16, 5.62] {
                bounds.push(b * m);
            }
            b *= 10.0;
        }
        let n = bounds.len();
        Self {
            bounds,
            counts: vec![0; n + 1],
            total: 0,
            sum: 0.0,
            dropped_samples: 0,
        }
    }

    /// Record one sample. Non-finite samples (NaN / ±inf from a poisoned
    /// timing source) are rejected and counted in
    /// [`Self::dropped_samples`] — the same NaN-safe stance
    /// [`percentile_sorted`] takes — so a single bad sample can never make
    /// `mean()` NaN forever or leave telemetry JSON non-round-trippable.
    pub fn record(&mut self, seconds: f64) {
        if !seconds.is_finite() {
            self.dropped_samples += 1;
            return;
        }
        let idx = self
            .bounds
            .iter()
            .position(|&b| seconds <= b)
            .unwrap_or(self.bounds.len());
        self.counts[idx] += 1;
        self.total += 1;
        self.sum += seconds;
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    /// Exact count of non-finite samples rejected by [`Self::record`].
    pub fn dropped_samples(&self) -> u64 {
        self.dropped_samples
    }

    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum / self.total as f64
        }
    }

    /// Fold another histogram's samples into this one (element-wise bucket
    /// addition). Every instance is built with the same log-spaced bounds
    /// (see [`Self::new`]), so merging loses nothing beyond the bucket
    /// resolution both sides already had — this is how the router
    /// aggregates per-replica latency into a per-model histogram.
    pub fn merge(&mut self, other: &Self) {
        debug_assert_eq!(self.bounds.len(), other.bounds.len());
        for (c, &o) in self.counts.iter_mut().zip(other.counts.iter()) {
            *c += o;
        }
        self.total += other.total;
        self.sum += other.sum;
        self.dropped_samples += other.dropped_samples;
    }

    /// Approximate quantile from bucket boundaries: the upper bound of the
    /// bucket holding the `⌈q·total⌉`-th sample. The target rank is clamped
    /// to ≥ 1, so `q = 0.0` reports the first *non-empty* bucket (the
    /// minimum sample's bucket) instead of `bounds[0]` — a rank of 0 would
    /// otherwise satisfy `cum >= target` at the very first bucket even
    /// when every sample sits in high buckets.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let target = ((q.clamp(0.0, 1.0) * self.total as f64).ceil() as u64).max(1);
        let mut cum = 0;
        for (i, &c) in self.counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            cum += c;
            if cum >= target {
                return if i < self.bounds.len() {
                    self.bounds[i]
                } else {
                    *self.bounds.last().unwrap()
                };
            }
        }
        *self.bounds.last().unwrap()
    }
}

/// Human-friendly duration formatting (ns/µs/ms/s). Negative durations
/// (clock skew between two timestamps) keep their sign in the natural
/// magnitude unit, and non-finite inputs are printed verbatim — neither
/// falls through to the `< 1e-6` branch as nanoseconds.
pub fn fmt_duration(seconds: f64) -> String {
    if !seconds.is_finite() {
        return format!("{seconds}s");
    }
    if seconds < 0.0 {
        return format!("-{}", fmt_duration(-seconds));
    }
    if seconds < 1e-6 {
        format!("{:.1}ns", seconds * 1e9)
    } else if seconds < 1e-3 {
        format!("{:.2}µs", seconds * 1e6)
    } else if seconds < 1.0 {
        format!("{:.3}ms", seconds * 1e3)
    } else {
        format!("{:.3}s", seconds)
    }
}

/// Human-friendly byte-count formatting.
pub fn fmt_bytes(bytes: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = bytes as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{bytes}B")
    } else {
        format!("{v:.2}{}", UNITS[u])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.median - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
    }

    #[test]
    fn summary_empty() {
        let s = Summary::of(&[]);
        assert_eq!(s.n, 0);
        assert_eq!(s.mean, 0.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert!((percentile_sorted(&xs, 0.5) - 5.0).abs() < 1e-12);
        assert_eq!(percentile_sorted(&xs, 0.0), 0.0);
        assert_eq!(percentile_sorted(&xs, 1.0), 10.0);
    }

    #[test]
    fn histogram_quantiles_monotone() {
        let mut h = LatencyHistogram::new();
        for i in 1..=1000 {
            h.record(i as f64 * 1e-5);
        }
        assert_eq!(h.count(), 1000);
        assert!(h.quantile(0.5) <= h.quantile(0.95));
        assert!(h.mean() > 0.0);
    }

    #[test]
    fn summary_survives_nan_sample() {
        // A NaN measurement must not abort the summary (total_cmp sorts
        // NaN after every finite value); the finite order statistics stay
        // meaningful.
        let s = Summary::of(&[2.0, f64::NAN, 1.0, 3.0]);
        assert_eq!(s.n, 4);
        assert_eq!(s.min, 1.0);
        assert!(s.max.is_nan());
        assert!(s.mean.is_nan());
        // Median of [1, 2, 3, NaN] interpolates between 2.0 and 3.0.
        assert!((s.median - 2.5).abs() < 1e-12);
    }

    #[test]
    fn percentile_endpoints_exact() {
        let xs = [1.0, 2.0, 4.0, 8.0];
        // Endpoints return the extreme samples exactly (no interpolation).
        assert_eq!(percentile_sorted(&xs, 0.0), 1.0);
        assert_eq!(percentile_sorted(&xs, 1.0), 8.0);
        // Out-of-range q clamps to the endpoints.
        assert_eq!(percentile_sorted(&xs, -0.5), 1.0);
        assert_eq!(percentile_sorted(&xs, 1.5), 8.0);
        // Interior q interpolates linearly between neighbors.
        assert!((percentile_sorted(&xs, 0.5) - 3.0).abs() < 1e-12);
        assert_eq!(percentile_sorted(&[], 0.5), 0.0);
    }

    #[test]
    fn histogram_empty_and_single() {
        let h = LatencyHistogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.quantile(0.5), 0.0);
        assert_eq!(h.mean(), 0.0);

        let mut h = LatencyHistogram::new();
        h.record(1e-3);
        assert_eq!(h.count(), 1);
        // Every quantile of a single sample lands in that sample's bucket:
        // the reported bound must bracket the measurement from above.
        let q50 = h.quantile(0.5);
        let q99 = h.quantile(0.99);
        assert_eq!(q50, q99);
        assert!(q50 >= 1e-3 && q50 <= 1.78e-3 * 1.0001);
    }

    #[test]
    fn histogram_all_equal_samples() {
        let mut h = LatencyHistogram::new();
        for _ in 0..100 {
            h.record(2e-4);
        }
        assert_eq!(h.count(), 100);
        // All mass in one bucket: every quantile reports the same bound.
        let (q01, q50, q99) = (h.quantile(0.01), h.quantile(0.5), h.quantile(0.99));
        assert_eq!(q01, q50);
        assert_eq!(q50, q99);
        assert!((h.mean() - 2e-4).abs() < 1e-12);
    }

    #[test]
    fn histogram_merge_equals_recording_into_one() {
        // Merging two histograms must be indistinguishable from having
        // recorded every sample into a single one.
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        let mut one = LatencyHistogram::new();
        for i in 1..=40 {
            let s = i as f64 * 3e-5;
            a.record(s);
            one.record(s);
        }
        for i in 1..=25 {
            let s = i as f64 * 2e-3;
            b.record(s);
            one.record(s);
        }
        a.merge(&b);
        assert_eq!(a.count(), one.count());
        assert_eq!(a.mean(), one.mean());
        for q in [0.01, 0.5, 0.9, 0.95, 0.99] {
            assert_eq!(a.quantile(q), one.quantile(q));
        }
        // Merging an empty histogram is a no-op.
        let before = (a.count(), a.mean(), a.quantile(0.5));
        a.merge(&LatencyHistogram::new());
        assert_eq!(before, (a.count(), a.mean(), a.quantile(0.5)));
    }

    #[test]
    fn formatting() {
        assert!(fmt_duration(2.5e-3).contains("ms"));
        assert!(fmt_duration(3.0).contains('s'));
        assert_eq!(fmt_bytes(512), "512B");
        assert!(fmt_bytes(10 * 1024 * 1024).contains("MiB"));
    }

    #[test]
    fn quantile_zero_no_longer_reports_one_microsecond_for_slow_samples() {
        // Old bug: q=0.0 gave target rank 0, so the scan satisfied
        // `cum >= target` at the very first (empty) bucket and reported
        // bounds[0] = 1µs even when every sample took seconds.
        let mut h = LatencyHistogram::new();
        for _ in 0..10 {
            h.record(2.0); // 2 seconds each
        }
        let q0 = h.quantile(0.0);
        assert!(
            q0 >= 1.0,
            "q=0 must land in the slow samples' bucket, got {q0}"
        );
        // q=0 and q=0.01 agree when all mass sits in one bucket.
        assert_eq!(q0, h.quantile(0.01));
        // Monotone through the full quantile range.
        assert!(h.quantile(0.0) <= h.quantile(1.0));
    }

    #[test]
    fn quantile_zero_fix_holds_through_router_merge_path() {
        // The router aggregates per-replica latency by merging histograms
        // (Metrics::aggregate → merge); the q=0 fix must survive that
        // path too: merging slow-only samples into a fresh histogram (the
        // aggregate accumulator starts empty) must not resurrect the
        // 1µs floor.
        let mut replica = LatencyHistogram::new();
        for _ in 0..5 {
            replica.record(0.5);
        }
        let mut aggregate = LatencyHistogram::new();
        aggregate.merge(&replica);
        assert!(aggregate.quantile(0.0) >= 0.5 * 0.99);
        assert_eq!(aggregate.quantile(0.0), replica.quantile(0.0));
    }

    #[test]
    fn nan_record_no_longer_poisons_mean_forever() {
        // Old bug: a NaN sample fell past every bound into the overflow
        // bucket and was added to `sum`, so mean() was NaN for the rest of
        // the histogram's life (and telemetry JSON exported NaN).
        let mut h = LatencyHistogram::new();
        h.record(1e-3);
        h.record(f64::NAN);
        h.record(f64::INFINITY);
        h.record(f64::NEG_INFINITY);
        assert_eq!(h.count(), 1, "non-finite samples are not recorded");
        assert_eq!(h.dropped_samples(), 3);
        assert!((h.mean() - 1e-3).abs() < 1e-15, "mean stays finite");
        assert!(h.quantile(0.99).is_finite());
    }

    #[test]
    fn merge_carries_dropped_sample_counts() {
        let mut a = LatencyHistogram::new();
        a.record(f64::NAN);
        let mut b = LatencyHistogram::new();
        b.record(f64::INFINITY);
        b.record(2e-4);
        a.merge(&b);
        assert_eq!(a.dropped_samples(), 2);
        assert_eq!(a.count(), 1);
        assert!(a.mean().is_finite());
    }

    #[test]
    fn fmt_duration_negative_no_longer_prints_as_nanoseconds() {
        // Old bug: -3.0 satisfied `seconds < 1e-6` and printed as
        // "-3000000000.0ns"; NaN/inf fell into the same branch.
        assert_eq!(fmt_duration(-3.0), "-3.000s");
        assert_eq!(fmt_duration(-2.5e-3), "-2.500ms");
        assert!(fmt_duration(-5e-7).ends_with("ns"));
        assert!(fmt_duration(f64::NAN).contains("NaN"));
        assert_eq!(fmt_duration(f64::INFINITY), "infs");
        assert_eq!(fmt_duration(f64::NEG_INFINITY), "-infs");
        assert!(fmt_duration(0.0).ends_with("ns"));
    }
}
