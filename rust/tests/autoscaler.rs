//! Scripted-load battery for the deterministic [`Autoscaler`] driving a
//! real DOF serving stack:
//!
//! * **Exact scale ticks** — under a scripted backlog the scaler fires Up
//!   at the exact logical tick the thresholds predict, with the exact
//!   replica counts and the observed interval peak in the event; cooldown
//!   hysteresis discards the very next observation.
//! * **Elasticity is arithmetic-free** — requests served before, during,
//!   and after scale-up/retire return **bitwise-identical** f32 results
//!   to direct engine calls, across worker pools of 1/2/4/8 threads
//!   (`DOF_THREADS` picks the pool width for the env-driven tests).
//! * **No request lost** — retirement publishes the shrunken dispatch
//!   list before draining the retiring replica, so concurrent clients
//!   (with one failover retry for the stale-handle race) complete every
//!   request; counters are asserted exactly.
//! * **Factories recompile nothing** — scaled-up replicas are spawned
//!   from a [`ReplicaFactory`] that rebuilds the engine from its spec;
//!   same spec → identical decomposition → identical bytes.

use std::sync::Arc;
use std::time::Duration;

use dof::coordinator::{
    Autoscaler, AutoscalerConfig, BatchPolicy, ModelServer, Router, RouterConfig, ScaleDirection,
};
use dof::graph::{builder::random_layers, mlp_graph, Act, Graph};
use dof::operators::{CoeffSpec, Operator};
use dof::parallel::Pool;
use dof::tensor::Tensor;
use dof::util::Xoshiro256;

/// Deterministic f32 request points for `(tag, client, iter)`.
fn points(tag: u64, client: usize, iter: usize, rows: usize, width: usize) -> Vec<f32> {
    let mut rng = Xoshiro256::new(
        0xA5CA ^ tag.wrapping_mul(0x9E37_79B9) ^ ((client as u64) << 32) ^ iter as u64,
    );
    (0..rows * width).map(|_| rng.normal() as f32).collect()
}

/// The serving cast: f32 points → f64 tensor (exact), engine output → f32.
fn expect_direct(
    op: &Operator,
    g: &Graph,
    pts: &[f32],
    rows: usize,
    width: usize,
) -> (Vec<f32>, Vec<f32>) {
    let x = Tensor::from_vec(
        &[rows, width],
        pts.iter().map(|&v| v as f64).collect::<Vec<f64>>(),
    );
    let r = op.dof_engine().compute(g, &x);
    let cast = |t: &Tensor| t.data().iter().map(|&v| v as f32).collect::<Vec<f32>>();
    (cast(&r.values), cast(&r.operator_values))
}

fn dof_model(n: usize, seed: u64, rng_seed: u64) -> (Graph, Operator) {
    let mut rng = Xoshiro256::new(rng_seed);
    let graph = mlp_graph(&random_layers(&[n, 7, 1], &mut rng), Act::Tanh);
    let op = Operator::from_spec(CoeffSpec::EllipticGram { n, rank: n, seed });
    (graph, op)
}

/// A fast-completing DOF replica: a 2-row request fills capacity 2 and
/// cuts (and completes) immediately.
fn fast_replica(graph: &Graph, op: &Operator, pool: Pool) -> ModelServer {
    ModelServer::spawn_dof(
        graph.clone(),
        op.dof_engine(),
        BatchPolicy {
            capacity: 2,
            max_wait: Duration::from_millis(1),
            max_wait_ticks: None,
        },
        pool,
        2,
    )
}

/// Register the scale-up spawn factory for `model`: rebuilds the operator
/// from its spec (identical decomposition, compile-cache hit) and spawns
/// a fast replica.
fn install_factory(
    router: &mut Router,
    model: &str,
    graph: &Graph,
    n: usize,
    seed: u64,
    pool: Pool,
) {
    let graph = graph.clone();
    let factory = move || {
        let op = Operator::from_spec(CoeffSpec::EllipticGram { n, rank: n, seed });
        fast_replica(&graph, &op, pool)
    };
    router.set_replica_factory(model, Box::new(factory)).unwrap();
}

/// Bounded poll for a router-observable condition; panics (instead of
/// hanging CI) if it never holds.
fn wait_for(router: &Router, what: &str, cond: impl Fn(&Router) -> bool) {
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    while !cond(router) {
        assert!(
            std::time::Instant::now() < deadline,
            "condition not reached within 10 s: {what}"
        );
        std::thread::sleep(Duration::from_millis(2));
    }
}

/// Scripted backlog: four requests park in replica 0's batcher (capacity
/// 64 is never filled, the wall deadline is 30 s away), so the interval
/// peak queue depth is exactly 4 when the scaler observes. The step at
/// tick 0 must fire Up with exact before/after counts; the immediate
/// second step is inside the cooldown window and must discard; live
/// traffic then steers to the new replica (lower dispatch score than the
/// backlogged one) and matches the direct oracle bitwise; shutdown drains
/// the parked requests without loss.
#[test]
fn parked_backlog_scales_up_at_exact_tick_and_cooldown_discards() {
    let n = 4;
    let (graph, op) = dof_model(n, 17, 0x5CA1E);
    let pool = Pool::from_env();
    let mut router = Router::new();
    router.register(
        "dof",
        ModelServer::spawn_dof(
            graph.clone(),
            op.dof_engine(),
            BatchPolicy {
                capacity: 64,
                max_wait: Duration::from_secs(30),
                max_wait_ticks: None,
            },
            pool,
            2,
        ),
    );
    install_factory(&mut router, "dof", &graph, n, 17, pool);
    let client = router.client("dof").unwrap();

    // Park exactly four 2-row requests on replica 0.
    let parked: Vec<_> = (0..4)
        .map(|c| {
            let client = client.clone();
            std::thread::spawn(move || {
                let pts = points(1, c, 0, 2, n);
                let resp = client.eval_blocking(pts.clone()).unwrap();
                (pts, resp)
            })
        })
        .collect();
    wait_for(&router, "4 requests parked on replica 0", |r| {
        let m = &r.snapshot()[0];
        m.queue_depth == 4 && m.replicas[0].server.received == 4
    });

    let mut scaler = Autoscaler::new(AutoscalerConfig {
        min_replicas: 1,
        max_replicas: 2,
        up_queue_depth: 4,
        down_queue_depth: 1,
        cooldown_ticks: 8,
        ..AutoscalerConfig::default()
    });

    // Tick 0: the observed interval peak (4) reaches the threshold → Up.
    let events = scaler.step(&mut router);
    assert_eq!(events.len(), 1, "exactly one scale event");
    let ev = &events[0];
    assert_eq!(ev.model, "dof");
    assert_eq!(ev.direction, ScaleDirection::Up);
    assert_eq!(ev.tick, 0, "fired at the exact observation tick");
    assert_eq!((ev.replicas_before, ev.replicas_after), (1, 2));
    assert_eq!(ev.interval_peak_queue_depth, 4, "exact backlog observed");
    assert_eq!(router.replica_count("dof"), Some(2));
    assert_eq!(router.snapshot()[0].epoch, 2, "scale-up bumped the epoch");

    // Same backlog, same tick: inside the cooldown window → discarded.
    assert!(
        scaler.step(&mut router).is_empty(),
        "cooldown must discard the immediate re-observation"
    );
    assert_eq!(router.replica_count("dof"), Some(2));

    // Live traffic now scores replica 1 (inflight 0) below the backlogged
    // replica 0 (inflight 4): every request lands on the new replica and
    // matches the direct engine bitwise.
    for it in 0..3 {
        let pts = points(2, 9, it, 2, n);
        let resp = client.eval_blocking(pts.clone()).unwrap();
        let (want_phi, want_lphi) = expect_direct(&op, &graph, &pts, 2, n);
        assert_eq!(resp.phi, want_phi, "scaled-up response not bitwise (it {it})");
        assert_eq!(resp.lphi, want_lphi);
    }
    {
        let m = &router.snapshot()[0];
        assert_eq!(m.replicas[1].completed, 3, "dispatch steered around the backlog");
        assert_eq!(m.replicas[1].attempts, 3);
        assert_eq!((m.dispatched, m.completed, m.failed), (7, 3, 0));
        assert_eq!(m.queue_depth, 4, "the parked backlog is still in flight");
    }

    // Past the cooldown the backlog still pins the interval peak at ≥ 4,
    // and the replica set is at max: no event may fire (dead band + cap).
    router.clock().advance(8);
    assert!(scaler.step(&mut router).is_empty(), "capped and backlogged: no event");
    assert_eq!(router.replica_count("dof"), Some(2));

    let snap = scaler.snapshot();
    assert_eq!((snap.scale_ups, snap.scale_downs), (1, 0));
    assert_eq!(snap.events.len(), 1);

    // Drain: the four parked requests are flushed and answered bitwise.
    router.shutdown();
    for j in parked {
        let (pts, resp) = j.join().expect("parked client panicked");
        let (want_phi, want_lphi) = expect_direct(&op, &graph, &pts, 2, n);
        assert_eq!(resp.phi, want_phi, "drained response not bitwise");
        assert_eq!(resp.lphi, want_lphi);
    }
}

/// Idle two-replica model: the scaler retires one replica at the exact
/// tick of the observation, the event records the exact interval peak
/// (1, from strictly sequential traffic), the epoch bumps, and traffic
/// after retirement still matches the direct oracle — no request lost.
#[test]
fn idle_model_scales_down_at_exact_tick_without_losing_requests() {
    let n = 3;
    let (graph, op) = dof_model(n, 23, 0xD02F);
    let pool = Pool::from_env();
    let mut router = Router::with_config(RouterConfig {
        retries: 1,
        ..RouterConfig::default()
    });
    router.register("dof", fast_replica(&graph, &op, pool));
    let second = fast_replica(&graph, &op, pool);
    router.add_replica("dof", second).unwrap();

    let client = router.client("dof").unwrap();
    // Sequential traffic: each request completes before the next, so the
    // queue-depth high-water mark is exactly 1.
    for it in 0..4 {
        let pts = points(3, 0, it, 2, n);
        let resp = client.eval_blocking(pts.clone()).unwrap();
        let (want_phi, want_lphi) = expect_direct(&op, &graph, &pts, 2, n);
        assert_eq!(resp.phi, want_phi, "pre-retire response not bitwise (it {it})");
        assert_eq!(resp.lphi, want_lphi);
    }

    router.clock().advance(5);
    let mut scaler = Autoscaler::new(AutoscalerConfig {
        min_replicas: 1,
        max_replicas: 2,
        up_queue_depth: 4,
        down_queue_depth: 1,
        cooldown_ticks: 3,
        ..AutoscalerConfig::default()
    });
    let events = scaler.step(&mut router);
    assert_eq!(events.len(), 1);
    let ev = &events[0];
    assert_eq!(ev.direction, ScaleDirection::Down);
    assert_eq!(ev.tick, 5, "fired at the exact observation tick");
    assert_eq!((ev.replicas_before, ev.replicas_after), (2, 1));
    assert_eq!(ev.interval_peak_queue_depth, 1, "sequential traffic peaks at 1");
    assert_eq!(router.replica_count("dof"), Some(1));
    assert_eq!(
        router.snapshot()[0].epoch,
        3,
        "register(1) + add_replica(2) + retire(3)"
    );

    // Inside the cooldown window, and at the floor afterwards: no event.
    assert!(scaler.step(&mut router).is_empty(), "cooldown discards");
    router.clock().advance(3);
    assert!(scaler.step(&mut router).is_empty(), "at min_replicas: no event");
    assert_eq!(router.replica_count("dof"), Some(1));

    // Post-retirement traffic (existing client, new epoch on its next
    // request) is still bitwise-exact and fully accounted.
    for it in 4..6 {
        let pts = points(3, 0, it, 2, n);
        let resp = client.eval_blocking(pts.clone()).unwrap();
        let (want_phi, want_lphi) = expect_direct(&op, &graph, &pts, 2, n);
        assert_eq!(resp.phi, want_phi, "post-retire response not bitwise (it {it})");
        assert_eq!(resp.lphi, want_lphi);
    }
    let m = &router.snapshot()[0];
    assert_eq!((m.dispatched, m.completed, m.failed), (6, 6, 0));
    let snap = scaler.snapshot();
    assert_eq!((snap.scale_ups, snap.scale_downs), (0, 1));
    router.shutdown();
}

/// Retirement under concurrent fire: four client threads hammer a model
/// while the scaler retires a replica mid-stream. The shrunken dispatch
/// list is published before the drain, and the one racy window — a
/// client holding the stale list sends to the retiring replica after its
/// channel closed — is covered by the failover retry. Every request must
/// complete bitwise; `failed` must be 0.
#[test]
fn retirement_under_concurrent_load_loses_no_requests() {
    let n = 3;
    let (graph, op) = dof_model(n, 29, 0xF1FE);
    let pool = Pool::from_env();
    let mut router = Router::with_config(RouterConfig {
        retries: 1,
        ..RouterConfig::default()
    });
    router.register("dof", fast_replica(&graph, &op, pool));
    install_factory(&mut router, "dof", &graph, n, 29, pool);
    assert_eq!(router.scale_up("dof").unwrap(), 2, "factory-grown second replica");

    let client = router.client("dof").unwrap();
    let graph2 = graph.clone();
    let op2 = Arc::new(op);
    let clients = 4;
    let per_client = 8;
    let joins: Vec<_> = (0..clients)
        .map(|c| {
            let client = client.clone();
            let graph = graph2.clone();
            let op = Arc::clone(&op2);
            std::thread::spawn(move || {
                for it in 0..per_client {
                    let pts = points(4, c, it, 2, n);
                    let resp = client.eval_blocking(pts.clone()).unwrap();
                    let (want_phi, want_lphi) = expect_direct(&op, &graph, &pts, 2, n);
                    assert_eq!(resp.phi, want_phi, "client {c} it {it} phi (bitwise)");
                    assert_eq!(resp.lphi, want_lphi, "client {c} it {it} L[φ] (bitwise)");
                }
            })
        })
        .collect();

    // Retire mid-stream: thresholds chosen so any observed peak (≤ 4
    // concurrent clients) reads as idle, with no cooldown in the way.
    wait_for(&router, "traffic reached the model", |r| {
        r.snapshot()[0].completed >= 4
    });
    let mut scaler = Autoscaler::new(AutoscalerConfig {
        min_replicas: 1,
        max_replicas: 2,
        up_queue_depth: 9,
        down_queue_depth: 8,
        cooldown_ticks: 0,
        ..AutoscalerConfig::default()
    });
    let events = scaler.step(&mut router);
    assert_eq!(events.len(), 1, "mid-stream retirement fired");
    assert_eq!(events[0].direction, ScaleDirection::Down);
    assert_eq!(router.replica_count("dof"), Some(1));

    for j in joins {
        j.join().expect("client thread panicked");
    }
    let m = &router.snapshot()[0];
    let sent = (clients * per_client) as u64;
    assert_eq!(m.completed, sent, "every request answered across retirement");
    assert_eq!(m.failed, 0, "no request lost");
    assert_eq!(m.dispatched, sent);
    assert_eq!(m.queue_depth, 0, "queue drained");
    // Cross-replica aggregate accounts for every attempt: the per-model
    // `server` snapshot sums the live replica and the retired one's
    // metrics are gone with it, so only assert the live set's coverage.
    assert_eq!(
        m.server.received,
        m.replicas.iter().map(|r| r.server.received).sum::<u64>(),
        "aggregated snapshot covers the live replica set exactly"
    );
    router.shutdown();
}

/// Routed results are bitwise identical across pool widths 1/2/4/8 while
/// the replica set grows and shrinks mid-sequence: shard boundaries are a
/// function of batch size only, and replica choice never touches the
/// computed bytes.
#[test]
fn scaling_is_bitwise_invisible_across_pool_widths() {
    let n = 4;
    let (graph, op) = dof_model(n, 31, 0xB17);
    let mut baseline: Option<Vec<(Vec<f32>, Vec<f32>)>> = None;
    for threads in [1usize, 2, 4, 8] {
        let pool = Pool::new(threads);
        let mut router = Router::new();
        router.register("dof", fast_replica(&graph, &op, pool));
        install_factory(&mut router, "dof", &graph, n, 31, pool);
        let client = router.client("dof").unwrap();
        let mut got = Vec::new();
        let run = |lo: usize, hi: usize, got: &mut Vec<(Vec<f32>, Vec<f32>)>| {
            for it in lo..hi {
                let rows = 1 + it % 4;
                let pts = points(5, 0, it, rows, n);
                let resp = client.eval_blocking(pts.clone()).unwrap();
                let (want_phi, want_lphi) = expect_direct(&op, &graph, &pts, rows, n);
                assert_eq!(resp.phi, want_phi, "width {threads} it {it} vs direct");
                assert_eq!(resp.lphi, want_lphi);
                got.push((resp.phi, resp.lphi));
            }
        };
        run(0, 3, &mut got); // before scaling
        assert_eq!(router.scale_up("dof").unwrap(), 2);
        run(3, 6, &mut got); // during (2 replicas)
        assert_eq!(router.retire_replica("dof").unwrap(), 1);
        run(6, 9, &mut got); // after retirement
        router.shutdown();
        match &baseline {
            None => baseline = Some(got),
            Some(b) => assert_eq!(b, &got, "pool width {threads} diverged bitwise"),
        }
    }
}

/// The floor grows an under-provisioned model without any load: one
/// factory spawn per step (bounded change per step), each at its own
/// tick, until `min_replicas` is met; further steps are no-ops.
#[test]
fn floor_grows_to_min_replicas_one_step_at_a_time() {
    let n = 3;
    let (graph, op) = dof_model(n, 37, 0xF100);
    let pool = Pool::from_env();
    let mut router = Router::new();
    router.register("dof", fast_replica(&graph, &op, pool));
    install_factory(&mut router, "dof", &graph, n, 37, pool);

    let mut scaler = Autoscaler::new(AutoscalerConfig {
        min_replicas: 3,
        max_replicas: 3,
        up_queue_depth: 100,
        down_queue_depth: 0,
        cooldown_ticks: 2,
        ..AutoscalerConfig::default()
    });
    for (tick, want) in [(0u64, 2usize), (2, 3)] {
        let events = scaler.step(&mut router);
        assert_eq!(events.len(), 1, "one spawn per step");
        assert_eq!(events[0].tick, tick);
        assert_eq!(events[0].replicas_after, want);
        assert_eq!(router.replica_count("dof"), Some(want));
        router.clock().advance(2);
    }
    assert!(scaler.step(&mut router).is_empty(), "at the floor: no event");

    // The grown set serves bitwise-exact results.
    let client = router.client("dof").unwrap();
    let pts = points(6, 0, 0, 2, n);
    let resp = client.eval_blocking(pts.clone()).unwrap();
    let (want_phi, want_lphi) = expect_direct(&op, &graph, &pts, 2, n);
    assert_eq!((resp.phi, resp.lphi), (want_phi, want_lphi));
    router.shutdown();
}
