//! Cache-soundness contract for the compile-once caches — all three
//! consumers ([`dof::plan::PlanCache`], [`dof::jet::cache::JetCache`],
//! [`dof::plan::hessian::HessianPlanCache`]) of the one generic
//! double-checked [`dof::util::KeyedCache`]:
//!
//! * **value moves hit** — mutating weight *values* under a fixed zero
//!   pattern (an Adam step) must return the cached program by pointer
//!   identity;
//! * **structure recompiles** — a weight becoming exactly `0.0`, a
//!   topology edit, or an operator `L`-pattern change must miss and
//!   recompile;
//! * **recompiled plans are sound** — the recompiled program's §3.2
//!   active-row sets (and everything downstream) are re-verified against a
//!   fresh reference-interpreter run, bitwise;
//! * **eviction stays sound** — a program pushed out past the cap
//!   recompiles on re-request, and the recompiled program is re-verified
//!   (the generic layer's own eviction/stats/racing-build mechanics are
//!   pinned by `rust/src/util/keyed_cache.rs` unit tests).

use std::sync::Arc;

use dof::autodiff::{DofEngine, HessianEngine, TangentArena};
use dof::graph::{builder::random_layers, mlp_graph, Act};
use dof::jet::cache::JetCache;
use dof::jet::{laplacian_terms, terms_from_symmetric, DirectionBasis, JetEngine};
use dof::linalg::LdlDecomposition;
use dof::plan::hessian::HessianPlanCache;
use dof::plan::{PlanCache, PlanOptions};
use dof::tensor::Tensor;
use dof::util::Xoshiro256;

fn random_symmetric(n: usize, rng: &mut Xoshiro256) -> Tensor {
    let b = Tensor::randn(&[n, n], rng);
    b.add(&b.transpose()).scale(0.5)
}

const OPTS: PlanOptions = PlanOptions {
    sparsity: true,
    lower_order_c: false,
};

/// The recompiled (or cached) program — the exact `Arc` the cache under
/// test returned — must execute bit-identically to a fresh interpreter
/// run: the active-row soundness re-verification.
fn verify_program_against_interpreter(
    eng: &DofEngine,
    program: &dof::plan::OperatorProgram,
    g: &dof::graph::Graph,
    x: &Tensor,
) {
    let planned = eng.execute(program, g, x);
    let reference = eng.compute_with_arena(g, x, &mut TangentArena::new());
    assert_eq!(planned.values, reference.values);
    assert_eq!(planned.operator_values, reference.operator_values);
    assert_eq!(planned.out_active, reference.out_active, "active rows drifted");
    assert_eq!(planned.out_tangent.data, reference.out_tangent.data);
    assert_eq!(planned.cost, reference.cost);
    assert_eq!(planned.peak_tangent_bytes, reference.peak_tangent_bytes);
}

#[test]
fn plan_cache_value_moves_hit_zero_pattern_recompiles() {
    let cache = PlanCache::new();
    let mut rng = Xoshiro256::new(5101);
    let mut layers = random_layers(&[4, 7, 1], &mut rng);
    let a = random_symmetric(4, &mut rng);
    let ldl = LdlDecomposition::of(&a);
    let g1 = mlp_graph(&layers, Act::Tanh);
    let p1 = cache.get_or_compile(&g1, &ldl, OPTS);

    // Adam-style value move: every weight nudged, zero pattern untouched.
    for (w, b) in layers.iter_mut() {
        for v in w.data_mut().iter_mut() {
            if *v != 0.0 {
                *v += 0.01;
            }
        }
        for v in b.iter_mut() {
            *v -= 0.005;
        }
    }
    let g2 = mlp_graph(&layers, Act::Tanh);
    let p2 = cache.get_or_compile(&g2, &ldl, OPTS);
    assert!(
        Arc::ptr_eq(&p1, &p2),
        "weight-value mutation must hit the cached plan"
    );
    assert_eq!(cache.stats().misses, 1);

    // A weight becoming exactly 0.0 changes the structural key…
    layers[0].0.set(2, 1, 0.0);
    let g3 = mlp_graph(&layers, Act::Tanh);
    let p3 = cache.get_or_compile(&g3, &ldl, OPTS);
    assert!(
        !Arc::ptr_eq(&p1, &p3),
        "a weight hitting exactly 0.0 must recompile (active-row soundness)"
    );
    assert_eq!(cache.stats().misses, 2);

    // …and the recompiled plan (the Arc the cache returned) is re-verified
    // against a fresh interpreter run.
    let x = Tensor::randn(&[5, 4], &mut rng);
    let eng = DofEngine::from_ldl(ldl);
    verify_program_against_interpreter(&eng, &p3, &g3, &x);
}

#[test]
fn plan_cache_structure_edit_recompiles() {
    let cache = PlanCache::new();
    let mut rng = Xoshiro256::new(5102);
    let layers = random_layers(&[3, 6, 1], &mut rng);
    let deeper = random_layers(&[3, 6, 6, 1], &mut rng);
    let a = random_symmetric(3, &mut rng);
    let ldl = LdlDecomposition::of(&a);
    let p1 = cache.get_or_compile(&mlp_graph(&layers, Act::Sin), &ldl, OPTS);
    let p2 = cache.get_or_compile(&mlp_graph(&deeper, Act::Sin), &ldl, OPTS);
    assert!(!Arc::ptr_eq(&p1, &p2), "topology edits must recompile");
    // Activation swap is a structure edit too.
    let p3 = cache.get_or_compile(&mlp_graph(&layers, Act::Tanh), &ldl, OPTS);
    assert!(!Arc::ptr_eq(&p1, &p3), "activation swap must recompile");
    assert_eq!(cache.stats().misses, 3);
}

#[test]
fn plan_cache_l_pattern_change_recompiles_and_stays_sound() {
    let cache = PlanCache::new();
    let mut rng = Xoshiro256::new(5103);
    let layers = random_layers(&[4, 8, 1], &mut rng);
    let g = mlp_graph(&layers, Act::Tanh);
    // Dense operator vs diagonal operator: different L zero patterns.
    let dense = LdlDecomposition::of(&random_symmetric(4, &mut rng));
    let mut diag = Tensor::eye(4);
    diag.set(2, 2, -1.0);
    let diagonal = LdlDecomposition::of(&diag);
    let p1 = cache.get_or_compile(&g, &dense, OPTS);
    let p2 = cache.get_or_compile(&g, &diagonal, OPTS);
    assert!(
        !Arc::ptr_eq(&p1, &p2),
        "operator L-pattern change must recompile"
    );
    // Same pattern again: hit.
    let p3 = cache.get_or_compile(&g, &diagonal, OPTS);
    assert!(Arc::ptr_eq(&p2, &p3));
    // Re-verify the recompiled (diagonal-operator) plan — the returned Arc
    // itself — end to end.
    let x = Tensor::randn(&[4, 4], &mut rng);
    verify_program_against_interpreter(&DofEngine::from_ldl(diagonal), &p3, &g, &x);
}

#[test]
fn jet_cache_value_moves_hit_structure_changes_recompile() {
    let cache = JetCache::new();
    let mut rng = Xoshiro256::new(5104);
    let mut layers = random_layers(&[3, 6, 1], &mut rng);
    let basis = DirectionBasis::from_terms(3, &laplacian_terms(3, 1.0), None);
    let g1 = mlp_graph(&layers, Act::Tanh);
    let p1 = cache.get_or_compile(&g1, &basis, false);

    // Value move: hit.
    for (w, _) in layers.iter_mut() {
        for v in w.data_mut().iter_mut() {
            if *v != 0.0 {
                *v *= 1.01;
            }
        }
    }
    let g2 = mlp_graph(&layers, Act::Tanh);
    let p2 = cache.get_or_compile(&g2, &basis, false);
    assert!(Arc::ptr_eq(&p1, &p2), "jet value moves must hit");

    // Weight hitting exactly 0.0: recompile.
    layers[0].0.set(1, 2, 0.0);
    let g3 = mlp_graph(&layers, Act::Tanh);
    let p3 = cache.get_or_compile(&g3, &basis, false);
    assert!(!Arc::ptr_eq(&p1, &p3), "jet zero-pattern change must recompile");

    // Direction-pattern change (dense second-order operator): recompile.
    let a = random_symmetric(3, &mut rng);
    let dense_basis = DirectionBasis::from_terms(3, &terms_from_symmetric(&a), None);
    let p4 = cache.get_or_compile(&g3, &dense_basis, false);
    assert!(!Arc::ptr_eq(&p3, &p4), "direction-pattern change must recompile");

    // has_c partitions the key space.
    let p5 = cache.get_or_compile(&g3, &basis, true);
    assert!(!Arc::ptr_eq(&p3, &p5), "has_c must partition the key space");

    // Recompiled jet program re-verified against a fresh jet interpreter.
    let x = Tensor::randn(&[3, 3], &mut rng).scale(0.5);
    let eng = JetEngine::new(dense_basis);
    let planned = eng.execute(&p4, &g3, &x);
    let reference = eng.compute_with_arena(&g3, &x, &mut TangentArena::new());
    assert_eq!(planned.values, reference.values);
    assert_eq!(planned.operator_values, reference.operator_values);
    assert_eq!(planned.out_jet.data, reference.out_jet.data);
    assert_eq!(planned.cost, reference.cost);
    assert_eq!(planned.peak_jet_bytes, reference.peak_jet_bytes);
}

#[test]
fn hessian_cache_value_moves_hit_structure_changes_recompile_and_stay_sound() {
    let cache = HessianPlanCache::new();
    let mut rng = Xoshiro256::new(5105);
    let mut layers = random_layers(&[4, 7, 1], &mut rng);
    let g1 = mlp_graph(&layers, Act::Tanh);
    let p1 = cache.get_or_compile(&g1);

    // Value move: hit by pointer identity (Hessian plans are keyed by
    // structure alone — the operator only enters the final contraction).
    for (w, b) in layers.iter_mut() {
        for v in w.data_mut().iter_mut() {
            if *v != 0.0 {
                *v += 0.02;
            }
        }
        for v in b.iter_mut() {
            *v += 0.01;
        }
    }
    let g2 = mlp_graph(&layers, Act::Tanh);
    let p2 = cache.get_or_compile(&g2);
    assert!(Arc::ptr_eq(&p1, &p2), "hessian value moves must hit");
    let st = cache.stats();
    assert_eq!((st.hits, st.misses, st.entries), (1, 1, 1));

    // A weight hitting exactly 0.0 is a structural edit: recompile.
    layers[0].0.set(1, 2, 0.0);
    let g3 = mlp_graph(&layers, Act::Tanh);
    let p3 = cache.get_or_compile(&g3);
    assert!(
        !Arc::ptr_eq(&p1, &p3),
        "hessian zero-pattern change must recompile"
    );
    assert_eq!(cache.stats().misses, 2);

    // The recompiled plan — the exact Arc the cache returned — re-verified
    // bitwise against the retained reference path.
    let a = {
        let b = Tensor::randn(&[4, 4], &mut rng);
        b.add(&b.transpose()).scale(0.5)
    };
    let x = Tensor::randn(&[4, 4], &mut rng).scale(0.5);
    let eng = HessianEngine::new(&a);
    let planned = eng.execute(&p3, &g3, &x);
    let reference = eng.compute_reference(&g3, &x);
    assert_eq!(planned.values, reference.values);
    assert_eq!(planned.gradient, reference.gradient);
    assert_eq!(planned.hessian, reference.hessian);
    assert_eq!(planned.operator_values, reference.operator_values);
    assert_eq!(planned.cost, reference.cost);
    assert_eq!(planned.peak_tangent_bytes, reference.peak_tangent_bytes);
}

#[test]
fn plan_cache_eviction_recompiles_soundly() {
    // Eviction through a real consumer: a cap-sized parade of distinct
    // architectures pushes the first program out; re-requesting it
    // recompiles (miss) and the recompiled program is verified bitwise.
    let cache = PlanCache::new();
    let mut rng = Xoshiro256::new(5106);
    let a = random_symmetric(3, &mut rng);
    let ldl = LdlDecomposition::of(&a);
    let first_layers = random_layers(&[3, 4, 1], &mut rng);
    let g_first = mlp_graph(&first_layers, Act::Tanh);
    let p_first = cache.get_or_compile(&g_first, &ldl, OPTS);
    // CACHE_CAP distinct structures (hidden widths 5..5+cap) evict it.
    for h in 0..dof::plan::cache::CACHE_CAP {
        let g = mlp_graph(&random_layers(&[3, 5 + h, 1], &mut rng), Act::Tanh);
        let _ = cache.get_or_compile(&g, &ldl, OPTS);
    }
    let misses_before = cache.stats().misses;
    let p_again = cache.get_or_compile(&g_first, &ldl, OPTS);
    assert_eq!(
        cache.stats().misses,
        misses_before + 1,
        "evicted program must recompile"
    );
    assert!(!Arc::ptr_eq(&p_first, &p_again));
    let x = Tensor::randn(&[3, 3], &mut rng);
    verify_program_against_interpreter(&DofEngine::from_ldl(ldl), &p_again, &g_first, &x);
}
