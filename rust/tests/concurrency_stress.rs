//! Concurrency battery for the serving runtime layer:
//!
//! * **Sharded slab pool** — N caller threads hammer unsharded `execute()`
//!   across distinct and shared `(program, rows)` keys on the hash-sharded
//!   pool; every result must stay bit-identical to a single-threaded
//!   baseline, checkouts must be exact-fit, and the DOF / Hessian / jet
//!   domains must never alias a slab key.
//! * **Persistent worker pool** — OS threads spawn exactly once per
//!   process (spawn-counter assertion) and region results are
//!   bit-identical to the retained scoped-spawn baseline
//!   ([`dof::parallel::Pool::run_sharded_scoped`]) across 1/2/4/8
//!   threads, for both raw regions and full engine `compute_sharded`
//!   passes.

use std::ops::Range;
use std::sync::Arc;

use dof::autodiff::{slab_pool_stats, with_program_slab, DofEngine, HessianEngine, SlabKey};
use dof::graph::{builder::random_layers, mlp_graph, Act, Graph};
use dof::jet::{terms_from_symmetric, DirectionBasis, JetEngine};
use dof::linalg::LdlDecomposition;
use dof::operators::CoeffSpec;
use dof::parallel::{pool, split_rows, Pool};
use dof::plan::hessian::hessian_key;
use dof::tensor::Tensor;
use dof::util::Xoshiro256;

fn random_symmetric(n: usize, rng: &mut Xoshiro256) -> Tensor {
    let b = Tensor::randn(&[n, n], rng);
    b.add(&b.transpose()).scale(0.5)
}

/// One `(graph, operator, input)` configuration shared across threads.
struct Config {
    graph: Graph,
    a: Tensor,
    x: Tensor,
}

fn configs() -> Vec<Config> {
    let mut rng = Xoshiro256::new(0x57AE55);
    let mut out = Vec::new();
    // Two distinct architectures/operators (distinct slab keys) ...
    for (n, hidden, batch) in [(4usize, 9usize, 9usize), (5, 12, 7)] {
        let graph = mlp_graph(&random_layers(&[n, hidden, 1], &mut rng), Act::Tanh);
        let a = random_symmetric(n, &mut rng);
        let x = Tensor::randn(&[batch, n], &mut rng).scale(0.5);
        out.push(Config { graph, a, x });
    }
    // ... plus the first architecture again at a different row count (same
    // program fingerprint, different `rows` — a distinct slab key that
    // must not alias the first).
    let first = &out[0];
    let graph = first.graph.clone();
    let a = first.a.clone();
    let x = Tensor::randn(&[4, 4], &mut rng).scale(0.5);
    out.push(Config { graph, a, x });
    out
}

#[test]
fn slab_keys_are_domain_tagged_and_row_distinct() {
    let cfgs = configs();
    let c = &cfgs[0];
    let ldl = LdlDecomposition::of(&c.a);
    let dof_fp = DofEngine::from_ldl(ldl).plan(&c.graph).key().fingerprint;
    let hes_fp = hessian_key(&c.graph).fingerprint;
    let basis = DirectionBasis::from_terms(c.a.dims()[0], &terms_from_symmetric(&c.a), None);
    let jet_fp = JetEngine::new(basis).plan(&c.graph).key().fingerprint;
    assert_ne!(dof_fp, hes_fp, "DOF and Hessian slabs must never alias");
    assert_ne!(dof_fp, jet_fp, "DOF and jet slabs must never alias");
    assert_ne!(hes_fp, jet_fp, "Hessian and jet slabs must never alias");
    // Same program at different row counts is a distinct key — the pool
    // hands back a slab sized for exactly (program, rows).
    let ka = SlabKey { program: dof_fp, rows: 9 };
    let kb = SlabKey { program: dof_fp, rows: 4 };
    assert_ne!(ka, kb);
}

#[test]
fn concurrent_unsharded_executions_bit_identical_and_exact_fit() {
    let cfgs = Arc::new(configs());

    // Single-threaded baselines for every engine × config.
    struct Baseline {
        dof_vals: Tensor,
        dof_ops: Tensor,
        hes_ops: Tensor,
        hes_hessian: Tensor,
        jet_ops: Tensor,
    }
    let baselines: Arc<Vec<Baseline>> = Arc::new(
        cfgs.iter()
            .map(|c| {
                let dof = DofEngine::new(&c.a).compute(&c.graph, &c.x);
                let hes = HessianEngine::new(&c.a).compute(&c.graph, &c.x);
                let basis = DirectionBasis::from_terms(
                    c.a.dims()[0],
                    &terms_from_symmetric(&c.a),
                    None,
                );
                let jet = JetEngine::new(basis).compute(&c.graph, &c.x);
                Baseline {
                    dof_vals: dof.values,
                    dof_ops: dof.operator_values,
                    hes_ops: hes.operator_values,
                    hes_hessian: hes.hessian,
                    jet_ops: jet.operator_values,
                }
            })
            .collect(),
    );

    // Hammer: 8 caller threads × 12 rounds over every (engine, config),
    // all on the unsharded `compute()` paths — exactly the access pattern
    // the hash-sharded slab pool exists for. Any cross-key or cross-domain
    // slab aliasing, lost checkout, or stale-length slab shows up as a
    // bitwise mismatch (executors assert exact slab sizing internally).
    let joins: Vec<_> = (0..8)
        .map(|t| {
            let cfgs = Arc::clone(&cfgs);
            let baselines = Arc::clone(&baselines);
            std::thread::spawn(move || {
                for round in 0..12 {
                    // Stagger the config order per thread so shared and
                    // distinct keys interleave differently each round.
                    for idx in 0..cfgs.len() {
                        let i = (idx + t + round) % cfgs.len();
                        let c = &cfgs[i];
                        let b = &baselines[i];
                        let dof = DofEngine::new(&c.a).compute(&c.graph, &c.x);
                        assert_eq!(dof.values, b.dof_vals, "dof values cfg {i}");
                        assert_eq!(dof.operator_values, b.dof_ops, "dof L[φ] cfg {i}");
                        let hes = HessianEngine::new(&c.a).compute(&c.graph, &c.x);
                        assert_eq!(hes.operator_values, b.hes_ops, "hessian L[φ] cfg {i}");
                        assert_eq!(hes.hessian, b.hes_hessian, "hessian H cfg {i}");
                        let basis = DirectionBasis::from_terms(
                            c.a.dims()[0],
                            &terms_from_symmetric(&c.a),
                            None,
                        );
                        let jet = JetEngine::new(basis).compute(&c.graph, &c.x);
                        assert_eq!(jet.operator_values, b.jet_ops, "jet L[φ] cfg {i}");
                    }
                }
            })
        })
        .collect();
    for j in joins {
        j.join().expect("stress thread panicked");
    }

    // Pool accounting: the hammer's checkouts were counted, and a warm
    // key's parked slab is exact-fit (tolerate eviction by a concurrently
    // running test — an absent slab is legal, a wrong-sized one is not).
    let st = slab_pool_stats();
    assert!(st.hits > 0, "steady-state hammer must hit the warm pool");
    let c = &cfgs[0];
    let eng = DofEngine::new(&c.a);
    let program = eng.plan(&c.graph);
    let rows = c.x.dims()[0];
    let key = SlabKey {
        program: program.key().fingerprint,
        rows,
    };
    let (len, want) = with_program_slab(key, |s| (s.len(), program.slab_len(rows)));
    if len != 0 {
        assert_eq!(len, want, "warm checkout must be exact-fit");
    }
}

#[test]
fn worker_pool_spawns_once_and_matches_scoped_baseline() {
    // Raw regions: order-sensitive float accumulation so any reduction
    // reorder between the pooled and scoped runtimes is visible.
    let work = |i: usize, r: Range<usize>| -> f64 {
        let mut acc = (i as f64) * 0.1;
        for x in r {
            acc += (x as f64) * 1.000_000_1 + acc * 1e-7;
        }
        acc
    };
    let ranges = split_rows(201, 8);
    let serial = Pool::new(1).run_sharded(ranges.clone(), work);
    for threads in [2usize, 4, 8] {
        let p = Pool::new(threads);
        let pooled = p.run_sharded(ranges.clone(), work);
        let scoped = p.run_sharded_scoped(ranges.clone(), work);
        assert_eq!(pooled, scoped, "pooled vs scoped at {threads} threads");
        assert_eq!(pooled, serial, "pooled vs serial at {threads} threads");
    }

    let s0 = pool::stats();
    assert_eq!(s0.spawn_events, 1, "the team spawns exactly once");
    assert!(s0.workers >= 1);

    // Full engine passes across the thread matrix, all on the pooled
    // runtime: values, L[φ], FLOPs, and per-shard peaks bit-identical.
    let mut rng = Xoshiro256::new(0x9001);
    let graph = mlp_graph(&random_layers(&[6, 14, 1], &mut rng), Act::Sin);
    let a = CoeffSpec::EllipticGram { n: 6, rank: 6, seed: 3 }.build();
    let x = Tensor::randn(&[21, 6], &mut rng).scale(0.5);
    let eng = DofEngine::new(&a);
    let hes = HessianEngine::new(&a);
    let dof_base = eng.compute_sharded(&graph, &x, &Pool::new(1), 4);
    let hes_base = hes.compute_sharded(&graph, &x, &Pool::new(1), 4);
    for threads in [2usize, 4, 8] {
        let p = Pool::new(threads);
        let d = eng.compute_sharded(&graph, &x, &p, 4);
        assert_eq!(d.values, dof_base.values);
        assert_eq!(d.operator_values, dof_base.operator_values);
        assert_eq!(d.cost, dof_base.cost);
        assert_eq!(d.peak_tangent_bytes, dof_base.peak_tangent_bytes);
        let h = hes.compute_sharded(&graph, &x, &p, 4);
        assert_eq!(h.values, hes_base.values);
        assert_eq!(h.operator_values, hes_base.operator_values);
        assert_eq!(h.hessian, hes_base.hessian);
        assert_eq!(h.cost, hes_base.cost);
        assert_eq!(h.peak_tangent_bytes, hes_base.peak_tangent_bytes);
    }

    // Zero thread creation after warmup, across all of the above.
    let s1 = pool::stats();
    assert_eq!(s1.spawn_events, 1, "no thread creation after warmup");
    assert_eq!(s1.workers, s0.workers, "team size is fixed for the process");
    assert!(s1.regions > s0.regions, "regions were actually dispatched");
}

#[test]
fn concurrent_sharded_and_unsharded_mix() {
    // Sharded regions (on the persistent team) racing unsharded callers
    // (on the hash-sharded slab pool) — the serving-shaped mixed workload.
    let mut rng = Xoshiro256::new(0xA11C);
    let graph = mlp_graph(&random_layers(&[4, 10, 1], &mut rng), Act::Tanh);
    let a = {
        let b = Tensor::randn(&[4, 4], &mut rng);
        b.add(&b.transpose()).scale(0.5)
    };
    let x = Tensor::randn(&[13, 4], &mut rng).scale(0.5);
    let base = DofEngine::new(&a).compute(&graph, &x);
    let graph = Arc::new(graph);
    let a = Arc::new(a);
    let x = Arc::new(x);
    let base_vals = Arc::new(base.values);
    let base_ops = Arc::new(base.operator_values);
    let joins: Vec<_> = (0..6)
        .map(|t| {
            let graph = Arc::clone(&graph);
            let a = Arc::clone(&a);
            let x = Arc::clone(&x);
            let base_vals = Arc::clone(&base_vals);
            let base_ops = Arc::clone(&base_ops);
            std::thread::spawn(move || {
                let eng = DofEngine::new(&a);
                for _ in 0..8 {
                    let res = if t % 2 == 0 {
                        eng.compute(&graph, &x)
                    } else {
                        eng.compute_sharded(&graph, &x, &Pool::new(4), 4)
                    };
                    assert_eq!(res.values, *base_vals);
                    assert_eq!(res.operator_values, *base_ops);
                }
            })
        })
        .collect();
    for j in joins {
        j.join().expect("mixed-workload thread panicked");
    }
}
