//! Randomized cross-engine differential harness — the oracle hierarchy,
//! fuzzed:
//!
//! 1. **interpreter oracle** — the DOF slab executor must be *bitwise*
//!    identical to the reference interpreter (shared kernels, different
//!    storage policy), and the program-scheduled Hessian executor bitwise
//!    identical to its reference path — including exact FLOP counts and
//!    peak tangent bytes (analytic replay ≡ measured tracker);
//! 2. **cross-engine** — DOF ≡ Hessian `L[φ]` at tolerance (two exact
//!    algorithms, different summation orders), order-2 jets ≡ DOF (values
//!    bitwise, `L[φ]` at tolerance);
//! 3. **finite differences** — everything ≡ a central finite difference of
//!    the graph's plain forward evaluation, the only oracle that shares no
//!    code with any engine;
//! 4. **stochastic (STDE)** — the sampled estimator's `φ` is bitwise
//!    identical to DOF (the value row is exact, never estimated), its
//!    `L[φ]` estimate lands within a few of its own reported standard
//!    errors of the exact answer, and a fixed seed replays the estimate
//!    bit-for-bit. `DOF_STDE_SAMPLES=<n>` raises the sample count (the
//!    scheduled CI job uses a larger count, tightening the bound).
//!
//! ≥200 seeded cases by default; `DOF_FUZZ_CASES=<n>` scales the run (the
//! scheduled CI job uses a larger count). Failures print the reproducing
//! case seed via `dof::prop::run_prop`.

use dof::autodiff::dof_tape::dof_forward_tape;
use dof::autodiff::{DofEngine, DofResult, HessianEngine, HessianResult, TangentArena};
use dof::graph::Graph;
use dof::jet::{
    terms_from_symmetric, DirectionBasis, DirectionSampling, JetEngine, StochasticJetEngine,
};
use dof::parallel::Pool;
use dof::plan::{OperatorProgram, PlanOptions};
use dof::prop::generator::{random_operator_case, OperatorCase};
use dof::prop::{close, run_prop, Gen, PropResult};
use dof::tensor::Tensor;

fn fuzz_cases() -> u64 {
    std::env::var("DOF_FUZZ_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(200)
}

fn dof_engine(case: &OperatorCase) -> DofEngine {
    DofEngine::new(&case.a).with_lower_order(case.b.clone(), case.c)
}

fn hessian_engine(case: &OperatorCase) -> HessianEngine {
    HessianEngine::new(&case.a).with_lower_order(case.b.clone(), case.c)
}

fn jet_engine(case: &OperatorCase) -> JetEngine {
    let n = case.n();
    let basis = DirectionBasis::from_terms(n, &terms_from_symmetric(&case.a), case.b.as_deref());
    JetEngine::new(basis).with_constant(case.c)
}

fn stde_samples() -> u32 {
    // Modest default: the acceptance bound scales with the estimator's own
    // reported std_error, so fewer samples loosen (never weaken) the check;
    // the scheduled job raises this to tighten it.
    std::env::var("DOF_STDE_SAMPLES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(128)
}

fn stochastic_engine(case: &OperatorCase, samples: u32, seed: u64) -> StochasticJetEngine {
    StochasticJetEngine::from_terms(
        case.n(),
        terms_from_symmetric(&case.a),
        DirectionSampling::Gaussian,
        samples,
        seed,
    )
    .with_lower_order(case.b.clone(), case.c)
}

fn assert_dof_bitwise(planned: &DofResult, reference: &DofResult, what: &str) -> PropResult {
    if planned.values != reference.values {
        return Err(format!("{what}: values differ"));
    }
    if planned.operator_values != reference.operator_values {
        return Err(format!("{what}: L[φ] differs"));
    }
    if planned.out_active != reference.out_active {
        return Err(format!("{what}: active rows differ"));
    }
    if planned.out_tangent.data != reference.out_tangent.data {
        return Err(format!("{what}: output tangent differs"));
    }
    if planned.cost != reference.cost {
        return Err(format!(
            "{what}: FLOPs {:?} vs {:?}",
            planned.cost, reference.cost
        ));
    }
    if planned.peak_tangent_bytes != reference.peak_tangent_bytes {
        return Err(format!(
            "{what}: peak {} vs {}",
            planned.peak_tangent_bytes, reference.peak_tangent_bytes
        ));
    }
    Ok(())
}

fn assert_hessian_bitwise(
    planned: &HessianResult,
    reference: &HessianResult,
    what: &str,
) -> PropResult {
    if planned.values != reference.values {
        return Err(format!("{what}: values differ"));
    }
    if planned.gradient != reference.gradient {
        return Err(format!("{what}: gradient differs"));
    }
    if planned.hessian != reference.hessian {
        return Err(format!("{what}: Hessian differs"));
    }
    if planned.operator_values != reference.operator_values {
        return Err(format!("{what}: L[φ] differs"));
    }
    if planned.cost != reference.cost {
        return Err(format!(
            "{what}: FLOPs {:?} (analytic) vs {:?} (measured)",
            planned.cost, reference.cost
        ));
    }
    if planned.peak_tangent_bytes != reference.peak_tangent_bytes {
        return Err(format!(
            "{what}: peak {} (analytic) vs {} (measured)",
            planned.peak_tangent_bytes, reference.peak_tangent_bytes
        ));
    }
    Ok(())
}

/// Central finite difference of `Σ a_ij ∂²_ij φ + Σ b_i ∂_i φ + c·φ` on the
/// graph's plain forward evaluation — the engine-independent oracle.
fn fd_operator(
    graph: &Graph,
    a: &Tensor,
    b: &Option<Vec<f64>>,
    c: Option<f64>,
    x: &[f64],
) -> f64 {
    let n = x.len();
    let f = |z: &[f64]| graph.eval(&Tensor::from_vec(&[1, n], z.to_vec())).item();
    let f0 = f(x);
    let h = 1e-4;
    let mut out = 0.0;
    for i in 0..n {
        for j in i..n {
            let aij = if i == j {
                a.at(i, i)
            } else {
                a.at(i, j) + a.at(j, i)
            };
            if aij == 0.0 {
                continue;
            }
            let hij = if i == j {
                let mut zp = x.to_vec();
                zp[i] += h;
                let mut zm = x.to_vec();
                zm[i] -= h;
                (f(&zp) - 2.0 * f0 + f(&zm)) / (h * h)
            } else {
                let mut zpp = x.to_vec();
                zpp[i] += h;
                zpp[j] += h;
                let mut zpm = x.to_vec();
                zpm[i] += h;
                zpm[j] -= h;
                let mut zmp = x.to_vec();
                zmp[i] -= h;
                zmp[j] += h;
                let mut zmm = x.to_vec();
                zmm[i] -= h;
                zmm[j] -= h;
                (f(&zpp) - f(&zpm) - f(&zmp) + f(&zmm)) / (4.0 * h * h)
            };
            out += aij * hij;
        }
    }
    if let Some(bv) = b {
        let hb = 1e-5;
        for (i, &bi) in bv.iter().enumerate() {
            if bi == 0.0 {
                continue;
            }
            let mut zp = x.to_vec();
            zp[i] += hb;
            let mut zm = x.to_vec();
            zm[i] -= hb;
            out += bi * (f(&zp) - f(&zm)) / (2.0 * hb);
        }
    }
    if let Some(cc) = c {
        out += cc * f0;
    }
    out
}

fn one_case(g: &mut Gen) -> PropResult {
    let case = random_operator_case(g);
    let what = |s: &str| format!("{} ({s})", case.family);

    // 1a. DOF slab executor ≡ reference interpreter, bitwise.
    let eng = dof_engine(&case);
    let planned = eng.compute(&case.graph, &case.x);
    let interp = eng.compute_with_arena(&case.graph, &case.x, &mut TangentArena::new());
    assert_dof_bitwise(&planned, &interp, &what("dof planned vs interpreter"))?;
    // …and occasionally the §3.2-off ablation too.
    if g.bool_with(0.3) {
        let dense = dof_engine(&case).dense();
        let dp = dense.compute(&case.graph, &case.x);
        let di = dense.compute_with_arena(&case.graph, &case.x, &mut TangentArena::new());
        assert_dof_bitwise(&dp, &di, &what("dense dof planned vs interpreter"))?;
        for bi in 0..case.batch() {
            close(
                dp.operator_values.at(bi, 0),
                planned.operator_values.at(bi, 0),
                1e-9,
            )
            .map_err(|e| format!("{}: sparse vs dense L[φ] row {bi}: {e}", case.family))?;
        }
    }

    // 1b. Program-scheduled Hessian ≡ reference path, bitwise (incl. the
    // analytic-vs-measured FLOP/peak equality).
    let hes = hessian_engine(&case);
    let hes_planned = hes.compute(&case.graph, &case.x);
    let hes_ref = hes.compute_reference(&case.graph, &case.x);
    assert_hessian_bitwise(&hes_planned, &hes_ref, &what("hessian planned vs reference"))?;

    // 2a. DOF ≡ Hessian L[φ] (two exact algorithms, tolerance).
    for bi in 0..case.batch() {
        close(
            planned.operator_values.at(bi, 0),
            hes_planned.operator_values.at(bi, 0),
            1e-6,
        )
        .map_err(|e| format!("{}: dof vs hessian row {bi}: {e}", case.family))?;
    }

    // 2b. Order-2 jets ≡ DOF: values bitwise, L[φ] at tolerance.
    let jet = jet_engine(&case).compute(&case.graph, &case.x);
    if jet.values != planned.values {
        return Err(what("jet vs dof: values differ bitwise"));
    }
    for bi in 0..case.batch() {
        close(
            jet.operator_values.at(bi, 0),
            planned.operator_values.at(bi, 0),
            1e-7,
        )
        .map_err(|e| format!("{}: jet vs dof row {bi}: {e}", case.family))?;
    }

    // 3. Everything ≡ central finite differences of the forward graph.
    for bi in 0..case.batch() {
        let fd = fd_operator(&case.graph, &case.a, &case.b, case.c, case.x.row(bi));
        close(planned.operator_values.at(bi, 0), fd, 2e-3)
            .map_err(|e| format!("{}: dof vs FD row {bi}: {e}", case.family))?;
    }

    // 4. Stochastic (STDE) fourth participant: φ bitwise vs DOF, the
    // estimate within a few of its own standard errors of the exact L[φ],
    // and the same seed replays the estimate bit-for-bit.
    let seed = g.rng().next_u64();
    let st_eng = stochastic_engine(&case, stde_samples(), seed);
    let st = st_eng.compute(&case.graph, &case.x);
    let st2 = st_eng.compute(&case.graph, &case.x);
    if st.operator_values != st2.operator_values || st.values != st2.values {
        return Err(what("stochastic estimate not seed-replayable"));
    }
    if st.values != planned.values {
        return Err(what("stochastic vs dof: φ values differ bitwise"));
    }
    for bi in 0..case.batch() {
        let exact = planned.operator_values.at(bi, 0);
        let est = st.operator_values.at(bi, 0);
        // 8 standard errors plus a floor for (near-)deterministic
        // operators whose reported variance is ~0.
        let tol = 8.0 * st.std_error.at(bi, 0) + 1e-6 * (1.0 + exact.abs());
        if (est - exact).abs() > tol {
            return Err(format!(
                "{}: stochastic row {bi}: estimate {est} vs exact {exact} \
                 exceeds {tol} ({} samples, seed {seed})",
                case.family,
                st.samples
            ));
        }
    }
    Ok(())
}

#[test]
fn cross_engine_differential_fuzz() {
    // Pinned base seed: deterministic in CI; DOF_FUZZ_CASES scales depth.
    run_prop("cross-engine differential", fuzz_cases(), 0xD0F4, one_case);
}

/// Accounting invariants on random graphs, all three engines: the compiled
/// program's analytic FLOP/peak equals the measured runtime counters.
#[test]
fn accounting_analytic_equals_measured_fuzz() {
    run_prop("analytic ≡ measured accounting", 25, 0xACC7, |g| {
        let case = random_operator_case(g);
        let batch = case.batch();

        // DOF: program analytics vs interpreter-measured counters.
        let eng = dof_engine(&case);
        let program = eng.plan(&case.graph);
        let interp = eng.compute_with_arena(&case.graph, &case.x, &mut TangentArena::new());
        if program.cost(batch) != interp.cost {
            return Err(format!(
                "dof analytic cost {:?} != measured {:?}",
                program.cost(batch),
                interp.cost
            ));
        }
        if program.peak_tangent_bytes(batch) != interp.peak_tangent_bytes {
            return Err(format!(
                "dof analytic peak {} != measured {}",
                program.peak_tangent_bytes(batch),
                interp.peak_tangent_bytes
            ));
        }

        // Hessian: plan analytics vs reference-measured counters.
        let hes = hessian_engine(&case);
        let planned = hes.compute(&case.graph, &case.x);
        let reference = hes.compute_reference(&case.graph, &case.x);
        if planned.cost != reference.cost {
            return Err(format!(
                "hessian analytic cost {:?} != measured {:?}",
                planned.cost, reference.cost
            ));
        }
        if planned.peak_tangent_bytes != reference.peak_tangent_bytes {
            return Err(format!(
                "hessian analytic peak {} != measured {}",
                planned.peak_tangent_bytes, reference.peak_tangent_bytes
            ));
        }

        // Training tape: since the cost-convention unification, the
        // retain-all forward tape charges the engines' exact FLOP
        // convention — its measured cost must equal the dense
        // (sparsity-off, no-c) program's analytic count exactly.
        let tape_program = OperatorProgram::compile(
            &case.graph,
            &eng.ldl,
            PlanOptions {
                sparsity: false,
                lower_order_c: false,
            },
        );
        let tape = dof_forward_tape(&case.graph, &eng.ldl, case.b.as_deref(), &case.x);
        if tape.cost != tape_program.cost(batch) {
            return Err(format!(
                "tape measured cost {:?} != dense program analytic {:?}",
                tape.cost,
                tape_program.cost(batch)
            ));
        }

        // Jet (order 2): program analytics vs interpreter-measured.
        let jeng = jet_engine(&case);
        let jprog = jeng.plan(&case.graph);
        let jref = jeng.compute_with_arena(&case.graph, &case.x, &mut TangentArena::new());
        if jprog.cost(batch) != jref.cost {
            return Err(format!(
                "jet analytic cost {:?} != measured {:?}",
                jprog.cost(batch),
                jref.cost
            ));
        }
        if jprog.peak_jet_bytes(batch) != jref.peak_jet_bytes {
            return Err(format!(
                "jet analytic peak {} != measured {}",
                jprog.peak_jet_bytes(batch),
                jref.peak_jet_bytes
            ));
        }
        Ok(())
    });
}

/// Poisoned-input family: every engine must reject a batch carrying
/// NaN/±Inf at seeded positions with the **identical** structured message
/// (they all delegate to the shared `tensor::ops::validate_batch_input`
/// gate) — and the rejection must happen *before* any propagation runs, so
/// a poisoned request can never warm a cache or emit a partial result.
#[test]
fn poisoned_inputs_rejected_identically_by_every_engine() {
    use dof::prop::generator::poisoned_operator_case;
    run_prop("poisoned-input rejection", 60, 0xBAD1, |g| {
        let p = poisoned_operator_case(g);
        let case = &p.case;
        let expected = match dof::tensor::ops::validate_batch_input(case.n(), &case.x) {
            Err(msg) => msg,
            Ok(()) => return Err("shared gate must reject poisoned input".into()),
        };
        if !expected.contains("non-finite input at row") {
            return Err(format!("unexpected gate message: {expected}"));
        }
        let engines: [(&str, Result<(), String>); 4] = [
            ("dof", dof_engine(case).validate_input(&case.graph, &case.x)),
            ("hessian", hessian_engine(case).validate_input(&case.graph, &case.x)),
            ("jet", jet_engine(case).validate_input(&case.graph, &case.x)),
            (
                "stochastic",
                stochastic_engine(case, 4, 1).validate_input(&case.graph, &case.x),
            ),
        ];
        for (name, res) in engines {
            match res {
                Err(msg) if msg == expected => {}
                Err(msg) => {
                    return Err(format!(
                        "{name} rejection differs: {msg:?} vs expected {expected:?}"
                    ));
                }
                Ok(()) => return Err(format!("{name} engine accepted poisoned input")),
            }
        }
        // Width mismatches are rejected identically too (engine-entry
        // validation, not just finiteness).
        let wrong = Tensor::zeros(&[2, case.n() + 1]);
        let e1 = dof_engine(case).validate_input(&case.graph, &wrong);
        let e2 = hessian_engine(case).validate_input(&case.graph, &wrong);
        let e3 = jet_engine(case).validate_input(&case.graph, &wrong);
        let e4 = stochastic_engine(case, 4, 1).validate_input(&case.graph, &wrong);
        if e1.is_ok() || e1 != e2 || e2 != e3 || e3 != e4 {
            return Err(format!(
                "width rejection differs: {e1:?} / {e2:?} / {e3:?} / {e4:?}"
            ));
        }
        Ok(())
    });
}

/// Determinism under sharding on random graphs: values, `L[φ]`, FLOPs, and
/// per-shard peaks are bit-identical across 1/2/4/8 threads on both the
/// DOF and the program-scheduled Hessian paths.
#[test]
fn sharded_runs_thread_invariant_fuzz() {
    run_prop("sharded thread invariance", 8, 0x7173, |g| {
        let case = random_operator_case(g);
        let n = case.n();
        // Multi-shard batch with a short last shard.
        let x = Tensor::randn(&[11, n], g.rng()).scale(0.5);
        let shard_rows = 4usize;

        let eng = dof_engine(&case);
        let dof_base = eng.compute_sharded(&case.graph, &x, &Pool::new(1), shard_rows);
        let hes = hessian_engine(&case);
        let hes_base = hes.compute_sharded(&case.graph, &x, &Pool::new(1), shard_rows);
        for threads in [2usize, 4, 8] {
            let pool = Pool::new(threads);
            let d = eng.compute_sharded(&case.graph, &x, &pool, shard_rows);
            if d.values != dof_base.values
                || d.operator_values != dof_base.operator_values
                || d.cost != dof_base.cost
                || d.peak_tangent_bytes != dof_base.peak_tangent_bytes
            {
                return Err(format!("dof not thread-invariant at {threads} threads"));
            }
            let h = hes.compute_sharded(&case.graph, &x, &pool, shard_rows);
            if h.values != hes_base.values
                || h.operator_values != hes_base.operator_values
                || h.hessian != hes_base.hessian
                || h.cost != hes_base.cost
                || h.peak_tangent_bytes != hes_base.peak_tangent_bytes
            {
                return Err(format!("hessian not thread-invariant at {threads} threads"));
            }
        }
        Ok(())
    });
}
