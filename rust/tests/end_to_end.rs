//! End-to-end integration: PINN training through DOF on every PDE in the
//! library, and the coordinator pipeline over a Rust-engine backend.

use std::time::Duration;

use dof::coordinator::{BatchPolicy, ModelServer};
use dof::graph::{mlp_graph, Act};
use dof::nn::{Mlp, MlpSpec};
use dof::operators::{CoeffSpec, Operator};
use dof::pde::trainer::{PinnConfig, PinnTrainer};
use dof::pde::{fokker_planck, heat_equation, klein_gordon, poisson};
use dof::train::AdamConfig;
use dof::tensor::Tensor;

fn small_model(in_dim: usize, seed: u64) -> Mlp {
    Mlp::init(
        MlpSpec {
            in_dim,
            hidden: 24,
            layers: 2,
            out_dim: 1,
            act: Act::Tanh,
        },
        seed,
    )
}

fn trains(problem: dof::pde::PdeProblem, steps: usize) -> (f64, f64) {
    let n = problem.operator.n();
    let cfg = PinnConfig {
        interior_batch: 32,
        boundary_batch: 16,
        boundary_weight: 10.0,
        adam: AdamConfig {
            lr: 3e-3,
            ..Default::default()
        },
        seed: 1,
    };
    let mut tr = PinnTrainer::new(problem, small_model(n, 9), cfg);
    let reports = tr.run(steps);
    let first: f64 = reports[..5.min(steps)]
        .iter()
        .map(|r| r.total_loss)
        .sum::<f64>()
        / 5.min(steps) as f64;
    let last: f64 = reports[steps.saturating_sub(5)..]
        .iter()
        .map(|r| r.total_loss)
        .sum::<f64>()
        / 5.min(steps) as f64;
    (first, last)
}

#[test]
fn every_pde_trains_through_dof() {
    for (name, problem) in [
        ("poisson", poisson(2)),
        ("heat", heat_equation(2)),
        ("klein-gordon", klein_gordon(1, 1.0)),
        ("fokker-planck", fokker_planck(3, 5)),
    ] {
        let (first, last) = trains(problem, 60);
        assert!(
            last.is_finite() && last < first,
            "{name}: loss did not decrease ({first:.4} → {last:.4})"
        );
    }
}

/// The coordinator serving a Rust-engine DOF backend end-to-end: responses
/// must match direct engine evaluation exactly.
#[test]
fn coordinator_serves_rust_dof_backend() {
    let n = 6;
    let model = small_model(n, 3);
    let graph = mlp_graph(&model.layers, Act::Tanh);
    let op = Operator::from_spec(CoeffSpec::EllipticGram { n, rank: n, seed: 2 });

    // Direct evaluation for ground truth.
    let mut rng = dof::util::Xoshiro256::new(77);
    let pts: Vec<f32> = (0..5 * n).map(|_| rng.normal() as f32).collect();
    let x64 = Tensor::from_vec(&[5, n], pts.iter().map(|&v| v as f64).collect());
    let direct = op.dof_engine().compute(&graph, &x64);

    // Serve through the batching coordinator.
    let graph2 = graph.clone();
    let engine = op.dof_engine();
    let compute: dof::coordinator::server::BatchFn =
        Box::new(move |data: &[f32], width: usize| {
            let rows = data.len() / width;
            let x = Tensor::from_vec(
                &[rows, width],
                data.iter().map(|&v| v as f64).collect::<Vec<f64>>(),
            );
            let res = engine.compute(&graph2, &x);
            Ok((
                res.values.data().iter().map(|&v| v as f32).collect(),
                res.operator_values.data().iter().map(|&v| v as f32).collect(),
            ))
        });
    let server = ModelServer::spawn(
        n,
        BatchPolicy {
            capacity: 8,
            max_wait: Duration::from_millis(1),
            max_wait_ticks: None,
        },
        compute,
    );
    let h = server.handle();
    let resp = h.eval_blocking(pts).unwrap();
    for b in 0..5 {
        let want = direct.operator_values.at(b, 0) as f32;
        assert!(
            (resp.lphi[b] - want).abs() <= 1e-4 * want.abs().max(1.0),
            "row {b}: served {} vs direct {want}",
            resp.lphi[b]
        );
    }
    let snap = h.metrics.snapshot();
    assert_eq!(snap.requests, 1);
    server.shutdown();
}

/// Low-rank PDE (heat: rank d of d+1) — the DOF tangent width must shrink
/// and training must still be exact enough to converge.
#[test]
fn heat_equation_exploits_low_rank() {
    let p = heat_equation(3);
    assert_eq!(p.operator.n(), 4);
    assert_eq!(p.operator.rank(), 3, "heat A should be rank-d");
    let (first, last) = trains(p, 40);
    assert!(last < first);
}

/// Training longer reaches a decent relative L2 error on Poisson 1+1D.
#[test]
#[ignore] // ~30s; run with --ignored for the full validation
fn poisson_reaches_low_error() {
    let cfg = PinnConfig {
        interior_batch: 64,
        boundary_batch: 32,
        boundary_weight: 20.0,
        adam: AdamConfig {
            lr: 3e-3,
            ..Default::default()
        },
        seed: 2,
    };
    let p = poisson(2);
    let mut tr = PinnTrainer::new(p, small_model(2, 4), cfg);
    tr.run(800);
    let err = tr.rel_l2_error(2048);
    assert!(err < 0.15, "relative L2 error {err:.3} too high");
}
