//! Deterministic fault-injection battery for the serving tier.
//!
//! Every test drives the stack through the seeded [`FaultInjector`]
//! (panics, NaN outputs, logical-latency, queue occupancy) and asserts
//! three things the fault tier promises:
//!
//! 1. **No crash, no deadlock** — injected faults fail *requests*, never
//!    workers; the storm test runs whole seeded schedules (serial and
//!    concurrent) under a watchdog.
//! 2. **Bitwise-identical successes** — a response that reports success is
//!    byte-for-byte what a fault-free run produces; fault handling may
//!    remove answers, never corrupt them.
//! 3. **Exact counters** — shed/deadline/engine-fault/retry accounting is
//!    asserted with `assert_eq!`, not `>=`: the injector schedule is a
//!    pure function of `(seed, config, k)`, so the expected counts are
//!    computed by replaying [`FaultInjector::plan_for`].
//!
//! Control-plane decisions (deadlines, quarantine windows) run on the
//! logical [`TickClock`] only; wall clock appears here solely as a harness
//! watchdog and in batcher `max_wait` (data plane — batch composition
//! cannot change per-row results).
//!
//! `DOF_FAULT_SEEDS=<n>` widens the storm's seed sweep (CI's weekly
//! fuzz-extended job raises it).

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use dof::coordinator::{
    BatchFn, BatchPolicy, FaultConfig, FaultInjector, HealthPolicy, HealthState, ModelServer,
    Router, RouterConfig, ServeConfig, ServeError, TickClock,
};
use dof::parallel::Pool;

fn policy(capacity: usize) -> BatchPolicy {
    BatchPolicy {
        capacity,
        max_wait: Duration::from_millis(1),
        max_wait_ticks: None,
    }
}

/// Deterministic mock backend: phi = row sum, lphi = 2·row sum. The
/// fault-free expectation for any request is computable in the test, which
/// is what makes "bitwise-identical success" assertable.
fn sum_compute() -> BatchFn {
    Box::new(|data: &[f32], width: usize| {
        let rows = data.len() / width;
        let mut phi = Vec::with_capacity(rows);
        let mut lphi = Vec::with_capacity(rows);
        for r in 0..rows {
            let s: f32 = data[r * width..(r + 1) * width].iter().sum();
            phi.push(s);
            lphi.push(2.0 * s);
        }
        Ok((phi, lphi))
    })
}

fn expected(points: &[f32], width: usize) -> (Vec<f32>, Vec<f32>) {
    let rows = points.len() / width;
    let phi: Vec<f32> = (0..rows)
        .map(|r| points[r * width..(r + 1) * width].iter().sum())
        .collect();
    let lphi: Vec<f32> = phi.iter().map(|s| 2.0 * s).collect();
    (phi, lphi)
}

/// Abort the process if a test wedges: a deadlocked router must fail CI,
/// not hang it. (Wall clock as a harness guard only.)
struct Watchdog {
    done: Arc<AtomicBool>,
}

impl Watchdog {
    fn arm(secs: u64, what: &'static str) -> Self {
        let done = Arc::new(AtomicBool::new(false));
        let flag = Arc::clone(&done);
        std::thread::spawn(move || {
            let deadline = std::time::Instant::now() + Duration::from_secs(secs);
            while std::time::Instant::now() < deadline {
                if flag.load(Ordering::Acquire) {
                    return;
                }
                std::thread::sleep(Duration::from_millis(50));
            }
            eprintln!("watchdog: {what} did not finish in {secs}s — likely deadlock");
            std::process::exit(2);
        });
        Self { done }
    }
}

impl Drop for Watchdog {
    fn drop(&mut self) {
        self.done.store(true, Ordering::Release);
    }
}

/// Serial traffic with capacity > rows and a tiny `max_wait` means one
/// request = one cut batch, so the k-th request consumes the injector's
/// k-th plan: the whole outcome sequence replays from the seed.
#[test]
fn injected_panics_are_contained_and_replay_exactly() {
    let _wd = Watchdog::arm(120, "panic containment test");
    let cfg = FaultConfig {
        panic_percent: 40,
        ..FaultConfig::default()
    };
    let seed = 0xC0FFEE;
    let injector = FaultInjector::new(seed, cfg);
    let server = ModelServer::spawn_cfg(
        2,
        policy(8),
        ServeConfig {
            injector: Some(Arc::clone(&injector)),
            ..ServeConfig::labeled("panicky")
        },
        sum_compute(),
    );
    let h = server.handle();
    let n_requests = 32u64;
    let mut panics_seen = 0u64;
    for k in 0..n_requests {
        let points = vec![k as f32, 0.5 * k as f32];
        let plan = FaultInjector::plan_for(seed, &cfg, k);
        match h.eval_blocking(points.clone()) {
            Ok(resp) => {
                assert!(!plan.panic, "batch {k}: schedule says panic, got success");
                let (phi, lphi) = expected(&points, 2);
                assert_eq!(resp.phi, phi, "batch {k}: phi not bitwise");
                assert_eq!(resp.lphi, lphi, "batch {k}: lphi not bitwise");
            }
            Err(e) => {
                assert!(plan.panic, "batch {k}: schedule says clean, got {e}");
                match &e {
                    ServeError::EngineFault { model, payload, .. } => {
                        assert_eq!(model, "panicky");
                        assert!(payload.contains("injected panic"), "{payload}");
                    }
                    other => panic!("batch {k}: expected EngineFault, got {other}"),
                }
                panics_seen += 1;
            }
        }
    }
    // Exact accounting: schedule, injector, and metrics all agree.
    let scheduled_panics = (0..n_requests)
        .filter(|&k| FaultInjector::plan_for(seed, &cfg, k).panic)
        .count() as u64;
    assert!(scheduled_panics >= 3, "seed too tame: {scheduled_panics}");
    assert!(
        scheduled_panics < n_requests,
        "seed too harsh: every batch panics"
    );
    assert_eq!(panics_seen, scheduled_panics);
    let isnap = injector.snapshot();
    assert_eq!(isnap.batches, n_requests);
    assert_eq!(isnap.injected_panics, scheduled_panics);
    let m = h.metrics.snapshot();
    assert_eq!(m.accepted, n_requests);
    assert_eq!(m.engine_faults, scheduled_panics);
    assert_eq!(m.requests, n_requests - scheduled_panics);
    assert_eq!((m.shed, m.invalid, m.deadline_expired), (0, 0, 0));
    server.shutdown();
}

/// A NaN produced inside the engine must be withheld at the boundary —
/// the client sees a structured EngineFault, never a NaN "success".
#[test]
fn injected_nan_outputs_never_reach_a_client() {
    let _wd = Watchdog::arm(120, "nan withholding test");
    let cfg = FaultConfig {
        nan_percent: 100,
        ..FaultConfig::default()
    };
    let injector = FaultInjector::new(7, cfg);
    let server = ModelServer::spawn_cfg(
        1,
        policy(4),
        ServeConfig {
            injector: Some(Arc::clone(&injector)),
            ..ServeConfig::labeled("poisoned")
        },
        sum_compute(),
    );
    let h = server.handle();
    for k in 0..8 {
        let err = h.eval_blocking(vec![k as f32]).unwrap_err();
        match &err {
            ServeError::EngineFault { payload, .. } => {
                assert!(payload.contains("non-finite engine output"), "{payload}");
            }
            other => panic!("expected EngineFault, got {other}"),
        }
    }
    let m = h.metrics.snapshot();
    assert_eq!(m.engine_faults, 8);
    assert_eq!(m.requests, 0, "no poisoned batch may complete");
    assert_eq!(injector.snapshot().injected_nans, 8);
    server.shutdown();
}

/// Latency injection is *logical*: it advances the shared TickClock by an
/// exact, replayable number of ticks — and wall time never expires a
/// deadline on its own.
#[test]
fn latency_injection_drives_the_logical_clock_exactly() {
    let _wd = Watchdog::arm(120, "logical latency test");
    let cfg = FaultConfig {
        latency_percent: 100,
        latency_ticks: 7,
        ..FaultConfig::default()
    };
    let clock = TickClock::new();
    let injector = FaultInjector::new(3, cfg);
    let server = ModelServer::spawn_cfg(
        1,
        policy(4),
        ServeConfig {
            clock: clock.clone(),
            injector: Some(Arc::clone(&injector)),
            ..ServeConfig::labeled("slow")
        },
        sum_compute(),
    );
    let h = server.handle();
    // Wall time passes; logical time must not.
    std::thread::sleep(Duration::from_millis(25));
    assert_eq!(clock.now(), 0);
    for k in 0..10 {
        // Generous logical deadline: never expires, every batch lands.
        let resp = h
            .eval_with_deadline(vec![k as f32], Some(clock.now() + 1000))
            .unwrap();
        assert_eq!(resp.phi, vec![k as f32]);
    }
    assert_eq!(clock.now(), 70, "10 batches × 7 injected ticks");
    assert_eq!(injector.snapshot().injected_latency_ticks, 70);
    // An already-expired logical deadline fails at dequeue — exactly one
    // deadline_expired, no batch consumed for it.
    let batches_before = injector.snapshot().batches;
    let err = h
        .eval_with_deadline(vec![1.0], Some(clock.now()))
        .unwrap_err();
    assert!(matches!(err, ServeError::DeadlineExceeded { .. }), "{err}");
    let m = h.metrics.snapshot();
    assert_eq!(m.deadline_expired, 1);
    assert_eq!(
        injector.snapshot().batches,
        batches_before,
        "an expired request must not consume a batch slot"
    );
    server.shutdown();
}

/// Scripted replica-failure schedule, exact to the request: a failing
/// prefix on replica 0 walks it to quarantine while every request fails
/// over to replica 1; once the logical probe window opens, one live
/// request probes replica 0 and re-admits it. Every counter is asserted
/// exactly.
#[test]
fn failover_quarantine_and_probe_readmission_schedule_is_exact() {
    let _wd = Watchdog::arm(120, "failover schedule test");
    let clock = TickClock::new();
    let inj_cfg = FaultConfig {
        panic_first: 2, // batches 0 and 1 on replica 0 panic, then clean
        ..FaultConfig::default()
    };
    let injector = FaultInjector::new(1, inj_cfg);
    let mut router = Router::with_config(RouterConfig {
        retries: 1,
        clock: clock.clone(),
        health: HealthPolicy {
            degrade_after: 1,
            quarantine_after: 2,
            probe_after_ticks: 4,
            probe_successes: 1,
        },
        ..RouterConfig::default()
    });
    router.register(
        "m",
        ModelServer::spawn_cfg(
            1,
            policy(4),
            ServeConfig {
                clock: clock.clone(),
                injector: Some(Arc::clone(&injector)),
                ..ServeConfig::labeled("m")
            },
            sum_compute(),
        ),
    );
    router
        .add_replica(
            "m",
            ModelServer::spawn_cfg(
                1,
                policy(4),
                ServeConfig {
                    clock: clock.clone(),
                    ..ServeConfig::labeled("m")
                },
                sum_compute(),
            ),
        )
        .unwrap();
    let client = router.client("m").unwrap();

    // Request A: replica 0 (batch 0) panics → Degraded; fails over to
    // replica 1 and succeeds bitwise.
    let resp = client.eval_blocking(vec![1.0]).unwrap();
    assert_eq!((resp.phi, resp.lphi), (vec![1.0], vec![2.0]));
    // Request B: replica 0 (batch 1) panics → Quarantined; fails over.
    let resp = client.eval_blocking(vec![2.0]).unwrap();
    assert_eq!(resp.lphi, vec![4.0]);
    let snap = router.snapshot();
    assert_eq!(snap[0].replicas[0].state, HealthState::Quarantined);
    assert_eq!(snap[0].quarantine_events, 1);
    // Request C: replica 0 gated (window 4 ticks, clock still 0) — served
    // by replica 1 with no retry burned.
    let resp = client.eval_blocking(vec![3.0]).unwrap();
    assert_eq!(resp.lphi, vec![6.0]);
    assert_eq!(router.snapshot()[0].retries, 2, "C must not retry");

    // Probe window opens on the logical clock; replica 0's injector prefix
    // is exhausted (batch 2 is clean), so the probe succeeds → Healthy.
    clock.advance(4);
    let resp = client.eval_blocking(vec![4.0]).unwrap();
    assert_eq!(resp.lphi, vec![8.0]);

    let snap = router.snapshot();
    let m = &snap[0];
    assert_eq!((m.dispatched, m.completed, m.failed), (4, 4, 0));
    assert_eq!(m.retries, 2);
    assert_eq!(m.engine_faults, 2);
    assert_eq!(m.quarantine_events, 1);
    assert_eq!(m.replicas[0].state, HealthState::Healthy);
    assert_eq!(
        (m.replicas[0].attempts, m.replicas[0].completed, m.replicas[0].failed),
        (3, 1, 2)
    );
    assert_eq!(
        (m.replicas[1].attempts, m.replicas[1].completed, m.replicas[1].failed),
        (3, 3, 0)
    );
    let isnap = injector.snapshot();
    assert_eq!(isnap.batches, 3);
    assert_eq!(isnap.injected_panics, 2);
    router.shutdown();
}

/// A shard panic inside a pooled batch carries its pool region label,
/// shard index, and row range all the way into the client's EngineFault.
#[test]
fn shard_panic_context_reaches_the_client() {
    let _wd = Watchdog::arm(120, "shard context test");
    let inner = |data: &[f32], width: usize| -> anyhow::Result<(Vec<f32>, Vec<f32>)> {
        let rows = data.len() / width;
        for r in 0..rows {
            if data[r * width] >= 100.0 {
                panic!("engine exploded on oversized value");
            }
        }
        Ok((vec![0.0; rows], vec![0.0; rows]))
    };
    let server = ModelServer::spawn_sharded_cfg(
        1,
        policy(8),
        Pool::new(2),
        2,
        ServeConfig::labeled("serve-m"),
        inner,
    );
    let h = server.handle();
    // 8 rows, shard_rows 2 → shards (0..2)(2..4)(4..6)(6..8); row 4 blows
    // up shard 2.
    let mut points = vec![0.0f32; 8];
    points[4] = 100.0;
    let err = h.eval_blocking(points).unwrap_err();
    match &err {
        ServeError::EngineFault {
            model,
            shard,
            payload,
        } => {
            assert_eq!(model, "serve-m");
            assert_eq!(*shard, Some(2), "payload: {payload}");
            assert!(
                payload.contains("pool region \"serve-m\" shard 2 (rows 4..6)"),
                "{payload}"
            );
            assert!(payload.contains("engine exploded on oversized value"), "{payload}");
        }
        other => panic!("expected EngineFault, got {other}"),
    }
    // The worker survived; clean rows still serve.
    let resp = h.eval_blocking(vec![1.0, 2.0]).unwrap();
    assert_eq!(resp.phi, vec![0.0, 0.0]);
    server.shutdown();
}

/// Real-engine variant: a DOF server under an injected panic schedule
/// against a fault-free twin. Successful responses must be bitwise equal —
/// the fault path may only remove answers, never change surviving ones.
#[test]
fn dof_engine_under_faults_matches_fault_free_twin_bitwise() {
    let _wd = Watchdog::arm(300, "dof fault twin test");
    use dof::graph::{builder::random_layers, mlp_graph, Act};
    use dof::operators::{CoeffSpec, Operator};
    use dof::util::Xoshiro256;
    let mut rng = Xoshiro256::new(512);
    let n = 3;
    let graph = mlp_graph(&random_layers(&[n, 8, 1], &mut rng), Act::Tanh);
    let op = Operator::from_spec(CoeffSpec::EllipticGram { n, rank: n, seed: 4 });
    let cfg = FaultConfig {
        panic_percent: 30,
        ..FaultConfig::default()
    };
    let seed = 0xD0F;
    let injector = FaultInjector::new(seed, cfg);
    let faulty = ModelServer::spawn_dof_cfg(
        graph.clone(),
        op.dof_engine(),
        policy(8),
        Pool::new(2),
        2,
        ServeConfig {
            injector: Some(Arc::clone(&injector)),
            ..ServeConfig::labeled("dof")
        },
    );
    let clean = ModelServer::spawn_dof(graph, op.dof_engine(), policy(8), Pool::new(2), 2);
    let hf = faulty.handle();
    let hc = clean.handle();
    let mut successes = 0u64;
    for k in 0..20u64 {
        let points: Vec<f32> = (0..2 * n).map(|i| 0.05 * (k * 7 + i as u64) as f32).collect();
        let baseline = hc.eval_blocking(points.clone()).unwrap();
        let plan = FaultInjector::plan_for(seed, &cfg, k);
        match hf.eval_blocking(points) {
            Ok(resp) => {
                assert!(!plan.panic, "batch {k}: schedule says panic");
                assert_eq!(resp.phi, baseline.phi, "batch {k}: phi diverged");
                assert_eq!(resp.lphi, baseline.lphi, "batch {k}: lphi diverged");
                successes += 1;
            }
            Err(e) => {
                assert!(plan.panic, "batch {k}: unscheduled failure {e}");
            }
        }
    }
    assert_eq!(
        successes,
        (0..20).filter(|&k| !FaultInjector::plan_for(seed, &cfg, k).panic).count() as u64
    );
    assert!(successes >= 3, "seed too harsh for a meaningful test");
    faulty.shutdown();
    clean.shutdown();
}

/// The storm: full fault mix (panics, NaN, logical latency, queue
/// occupancy) on both replicas, serial then concurrent traffic, multiple
/// seeds. The router must neither crash nor deadlock, every success must
/// be bitwise-exact, and the accounting identities must hold exactly.
#[test]
fn seeded_fault_storm_never_deadlocks_and_accounts_exactly() {
    let _wd = Watchdog::arm(300, "fault storm");
    let n_seeds: u64 = std::env::var("DOF_FAULT_SEEDS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(3);
    for s in 0..n_seeds {
        let seed = 0x57AB + s * 7919;
        run_storm(seed);
    }
}

fn run_storm(seed: u64) {
    let width = 2usize;
    let clock = TickClock::new();
    let inj_cfg = FaultConfig {
        panic_percent: 25,
        nan_percent: 20,
        latency_percent: 30,
        latency_ticks: 3,
        occupy_percent: 25,
        occupy_slots: 2,
        ..FaultConfig::default()
    };
    let mut router = Router::with_config(RouterConfig {
        retries: 2,
        clock: clock.clone(),
        ..RouterConfig::default()
    });
    let mk_server = |inj_seed: u64| {
        ModelServer::spawn_cfg(
            width,
            policy(8),
            ServeConfig {
                queue_cap: 16,
                clock: clock.clone(),
                injector: Some(FaultInjector::new(inj_seed, inj_cfg)),
                ..ServeConfig::labeled("storm")
            },
            sum_compute(),
        )
    };
    router.register("storm", mk_server(seed));
    router.add_replica("storm", mk_server(seed ^ 0xABCD)).unwrap();
    let client = router.client("storm").unwrap();

    let check = |resp: Result<dof::coordinator::EvalResponse, ServeError>, points: &[f32]| {
        match resp {
            Ok(r) => {
                let (phi, lphi) = expected(points, width);
                assert_eq!(r.phi, phi, "seed {seed}: success not bitwise");
                assert_eq!(r.lphi, lphi, "seed {seed}: success not bitwise");
            }
            Err(e) => {
                // Structured failure only — and never InvalidRequest: all
                // inputs here are well-formed.
                assert!(
                    !matches!(e, ServeError::InvalidRequest { .. }),
                    "seed {seed}: spurious InvalidRequest {e}"
                );
            }
        }
    };

    // Serial phase.
    for k in 0..40u64 {
        let points: Vec<f32> = (0..width).map(|i| (k * 3 + i as u64) as f32 * 0.25).collect();
        check(client.eval_blocking(points.clone()), &points);
    }
    // Concurrent phase: 4 clients × 10 requests.
    let joins: Vec<_> = (0..4u64)
        .map(|t| {
            let c = client.clone();
            std::thread::spawn(move || {
                for k in 0..10u64 {
                    let points: Vec<f32> =
                        (0..width).map(|i| (t * 100 + k * 3 + i as u64) as f32 * 0.25).collect();
                    let resp = c.eval_blocking(points.clone());
                    if let Ok(r) = resp {
                        let rows = points.len() / width;
                        let phi: Vec<f32> = (0..rows)
                            .map(|r| points[r * width..(r + 1) * width].iter().sum())
                            .collect();
                        assert_eq!(r.phi, phi, "concurrent success not bitwise");
                    }
                }
            })
        })
        .collect();
    for j in joins {
        j.join().expect("storm client panicked");
    }

    // Exact accounting identities.
    let snap = router.snapshot();
    let m = &snap[0];
    assert_eq!(m.queue_depth, 0, "seed {seed}: requests still in flight");
    assert_eq!(m.dispatched, 80, "seed {seed}");
    assert_eq!(
        m.dispatched,
        m.completed + m.failed,
        "seed {seed}: dispatched != completed + failed"
    );
    // Every attempt iteration beyond a request's first increments
    // `retries`, but an iteration where no replica is available (all
    // quarantined) reaches none — so dispatched + retries bounds attempts
    // from above, and completions bound it from below.
    let attempts: u64 = m.replicas.iter().map(|r| r.attempts).sum();
    assert!(
        attempts <= m.dispatched + m.retries,
        "seed {seed}: attempts {attempts} > dispatched {} + retries {}",
        m.dispatched,
        m.retries
    );
    assert!(attempts >= m.completed, "seed {seed}");
    let replica_completed: u64 = m.replicas.iter().map(|r| r.completed).sum();
    assert_eq!(replica_completed, m.completed, "seed {seed}");
    for r in &m.replicas {
        // Front-door trichotomy: every attempt is invalid, shed, or
        // accepted — exactly.
        assert_eq!(
            r.server.accepted + r.server.shed + r.server.invalid,
            r.attempts,
            "seed {seed} replica {}: front-door counters drift",
            r.index
        );
        assert_eq!(r.server.invalid, 0, "seed {seed}: no invalid inputs sent");
        assert_eq!(r.inflight, 0, "seed {seed}: admission slots leaked");
    }
    router.shutdown();
}
