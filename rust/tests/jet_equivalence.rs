//! The jet subsystem's contract, tested differentially:
//!
//! * **order-2 cross-check** — the jet path at `k = 2` (directions = rows
//!   of `L`, weights `2·sign` on `c₂`) reproduces the `DofEngine`
//!   Laplacian: values bit-identical (same GEMM kernels, row-independent),
//!   `L[φ]` to summation-order precision (the two algorithms sum the same
//!   exact real terms in different orders — the same reason the Hessian
//!   baseline is compared at tolerance), peak accounting comparable;
//! * **order-4 oracle** — biharmonic `Δ²φ` against a central finite
//!   difference of the *exactly computed* `DofEngine` Laplacian
//!   (`Δ²φ = Σᵢ ∂²ᵢ(Δφ)`), 1e-6 relative, on both shipped architectures;
//! * **determinism** — sharded jet execution is bit-identical (values,
//!   `L[φ]`, output jet, FLOP counts, per-shard peak bytes) across
//!   1/2/4/8 threads and matches the unsharded run exactly;
//! * **planned vs interpreter** — the slab executor is bit-identical to
//!   the retained reference interpreter (shared per-component kernels).

use dof::autodiff::{DofEngine, TangentArena};
use dof::graph::{builder::random_layers, mlp_graph, sparse_mlp_graph, Act, Graph};
use dof::jet::{terms_from_symmetric, DirectionBasis, JetEngine, JetResult};
use dof::operators::{HigherOrderOperator, HigherOrderSpec};
use dof::parallel::Pool;
use dof::tensor::Tensor;
use dof::util::Xoshiro256;

/// Laplacian jet engine at order 2: one direction per axis, weight `2` on
/// `c₂` (so `Σᵢ 2c₂^{(i)} = Σᵢ ∂²ᵢφ = Δφ`).
fn laplacian_jets(n: usize) -> JetEngine {
    JetEngine::new(DirectionBasis::from_terms(
        n,
        &dof::jet::laplacian_terms(n, 1.0),
        None,
    ))
}

#[test]
fn order2_laplacian_matches_dof_engine_mlp_across_thread_counts() {
    let mut rng = Xoshiro256::new(3101);
    let n = 6;
    let g = mlp_graph(&random_layers(&[n, 20, 20, 1], &mut rng), Act::Tanh);
    // Multi-shard batch so the 2/4/8-thread sweeps genuinely parallelize.
    let x = Tensor::randn(&[21, n], &mut rng);
    let jet_engine = laplacian_jets(n);
    let dof_engine = DofEngine::new(&Tensor::eye(n));
    let shard_rows = 8usize;
    let jet1 = jet_engine.compute_sharded(&g, &x, &Pool::new(1), shard_rows);
    for threads in [1usize, 2, 4, 8] {
        let pool = Pool::new(threads);
        let jet = jet_engine.compute_sharded(&g, &x, &pool, shard_rows);
        let dof = dof_engine.compute_sharded(&g, &x, &pool, shard_rows);
        // Values go through identical row-independent kernels → the two
        // *algorithms* agree bitwise, at every thread count.
        assert_eq!(
            jet.values, dof.values,
            "values must be bit-identical at {threads} threads"
        );
        // The jet path itself is bit-identical across thread counts
        // (values, L[φ], jet, FLOPs, per-shard peaks).
        assert_jet_bit_identical(&jet, &jet1, &format!("order-2, {threads} threads"));
        // L[φ]: both sum the same exact real terms, in different orders
        // (DOF collapses directions into one s-stream per node; jets carry
        // per-direction c₂ and contract at the output) — equality is to
        // float-summation order, the same reason the Hessian baseline is
        // compared at tolerance.
        for b in 0..21 {
            let jv = jet.operator_values.at(b, 0);
            let dv = dof.operator_values.at(b, 0);
            assert!(
                (jv - dv).abs() < 1e-10 * dv.abs().max(1.0),
                "row {b} at {threads} threads: jet Δφ {jv} vs DOF {dv}"
            );
        }
        // Peak accounting comparable: both report batch-linear per-shard
        // footprints; the jet carries (k+1) rows per direction vs DOF's
        // one, so the ratio is bounded by a small constant.
        assert!(jet.peak_jet_bytes > 0 && dof.peak_tangent_bytes > 0);
        assert!(jet.peak_jet_bytes <= 4 * dof.peak_tangent_bytes);
    }
}

#[test]
fn order2_laplacian_matches_dof_engine_sparse_arch() {
    let mut rng = Xoshiro256::new(3102);
    let blocks: Vec<_> = (0..3)
        .map(|_| random_layers(&[2, 8, 4], &mut rng))
        .collect();
    let g = sparse_mlp_graph(&blocks, Act::Sin);
    let n = 6;
    let x = Tensor::randn(&[5, n], &mut rng).scale(0.4);
    let jet = laplacian_jets(n).compute(&g, &x);
    // Compare against the *dense* DOF engine: its value stream performs the
    // same row-independent ops (§3.2 pruning only affects tangent rows).
    let dof = DofEngine::new(&Tensor::eye(n)).dense().compute(&g, &x);
    assert_eq!(jet.values, dof.values, "values must be bit-identical");
    for b in 0..5 {
        let jv = jet.operator_values.at(b, 0);
        let dv = dof.operator_values.at(b, 0);
        assert!(
            (jv - dv).abs() < 1e-10 * dv.abs().max(1.0),
            "row {b}: jet Δφ {jv} vs DOF {dv}"
        );
    }
}

#[test]
fn order2_general_operator_matches_dof_engine() {
    // Full polarization at order 2: random symmetric A as term list.
    let mut rng = Xoshiro256::new(3103);
    let n = 5;
    let g = mlp_graph(&random_layers(&[n, 14, 1], &mut rng), Act::Tanh);
    let x = Tensor::randn(&[4, n], &mut rng);
    let b = Tensor::randn(&[n, n], &mut rng);
    let a = b.add(&b.transpose()).scale(0.5);
    let basis = DirectionBasis::from_terms(n, &terms_from_symmetric(&a), None);
    let jet = JetEngine::new(basis).compute(&g, &x);
    let dof = DofEngine::new(&a).compute(&g, &x);
    for bi in 0..4 {
        let jv = jet.operator_values.at(bi, 0);
        let dv = dof.operator_values.at(bi, 0);
        assert!(
            (jv - dv).abs() < 1e-9 * dv.abs().max(1.0),
            "row {bi}: jet {jv} vs DOF {dv}"
        );
    }
}

/// FD oracle for `Δ²φ`: second central difference of the exactly computed
/// `DofEngine` Laplacian, `Δ²φ(x) ≈ Σᵢ [Δφ(x+heᵢ) − 2Δφ(x) + Δφ(x−heᵢ)]/h²`.
/// Differencing an exact smooth quantity keeps the error at
/// `O(h²) + O(ε/h²)` ≈ 1e-8 for `h = 1e-4`.
fn fd_biharmonic(g: &Graph, x: &[f64]) -> f64 {
    let n = x.len();
    let eng = DofEngine::new(&Tensor::eye(n));
    let lap = |z: &[f64]| -> f64 {
        eng.compute(g, &Tensor::from_vec(&[1, n], z.to_vec()))
            .operator_values
            .item()
    };
    let h = 1e-4;
    let center = lap(x);
    let mut out = 0.0;
    for i in 0..n {
        let mut zp = x.to_vec();
        let mut zm = x.to_vec();
        zp[i] += h;
        zm[i] -= h;
        out += (lap(&zp) - 2.0 * center + lap(&zm)) / (h * h);
    }
    out
}

#[test]
fn biharmonic_matches_fd_oracle_mlp() {
    let mut rng = Xoshiro256::new(3104);
    let n = 4;
    let g = mlp_graph(&random_layers(&[n, 12, 12, 1], &mut rng), Act::Tanh);
    let op = HigherOrderOperator::from_spec(HigherOrderSpec::Biharmonic { d: n });
    let engine = op.jet_engine();
    let x = Tensor::randn(&[3, n], &mut rng).scale(0.5);
    let res = engine.compute(&g, &x);
    for b in 0..3 {
        let got = res.operator_values.at(b, 0);
        let want = fd_biharmonic(&g, x.row(b));
        assert!(
            (got - want).abs() < 1e-6 * want.abs().max(1.0),
            "row {b}: jet Δ²φ {got} vs FD oracle {want}"
        );
    }
}

#[test]
fn biharmonic_matches_fd_oracle_sparse_arch() {
    let mut rng = Xoshiro256::new(3105);
    let blocks: Vec<_> = (0..2)
        .map(|_| random_layers(&[2, 8, 3], &mut rng))
        .collect();
    let g = sparse_mlp_graph(&blocks, Act::Tanh);
    let n = 4;
    let op = HigherOrderOperator::from_spec(HigherOrderSpec::Biharmonic { d: n });
    let engine = op.jet_engine();
    let x = Tensor::randn(&[2, n], &mut rng).scale(0.4);
    let res = engine.compute(&g, &x);
    for b in 0..2 {
        let got = res.operator_values.at(b, 0);
        let want = fd_biharmonic(&g, x.row(b));
        assert!(
            (got - want).abs() < 1e-6 * want.abs().max(1.0),
            "row {b}: jet Δ²φ {got} vs FD oracle {want}"
        );
    }
}

#[test]
fn mixed_third_and_fourth_order_terms_match_nested_oracle() {
    // L = ∂³/∂x₀²∂x₁ — oracle: first central difference over x₁ of the
    // exactly computed ∂²₀₀φ (DofEngine with A = e₀e₀ᵀ).
    let mut rng = Xoshiro256::new(3106);
    let n = 3;
    let g = mlp_graph(&random_layers(&[n, 10, 1], &mut rng), Act::Sin);
    let basis = DirectionBasis::from_terms(
        n,
        &[dof::jet::JetTerm::new(&[0, 0, 1], 1.0)],
        None,
    );
    let engine = JetEngine::new(basis);
    let x = Tensor::randn(&[2, n], &mut rng).scale(0.5);
    let res = engine.compute(&g, &x);
    let mut a00 = Tensor::zeros(&[n, n]);
    a00.set(0, 0, 1.0);
    let d00 = DofEngine::new(&a00);
    let h = 1e-5;
    for b in 0..2 {
        let mut zp = x.row(b).to_vec();
        let mut zm = x.row(b).to_vec();
        zp[1] += h;
        zm[1] -= h;
        let fp = d00
            .compute(&g, &Tensor::from_vec(&[1, n], zp))
            .operator_values
            .item();
        let fm = d00
            .compute(&g, &Tensor::from_vec(&[1, n], zm))
            .operator_values
            .item();
        let want = (fp - fm) / (2.0 * h);
        let got = res.operator_values.at(b, 0);
        assert!(
            (got - want).abs() < 1e-6 * want.abs().max(1.0),
            "row {b}: jet ∂³₀₀₁φ {got} vs oracle {want}"
        );
    }
}

#[test]
fn swift_hohenberg_problem_source_consistency() {
    // End-to-end: represent the exact sine solution as a graph
    // (Linear → Sin → Linear) and check the jet-computed L_SH[u*] equals
    // the manufactured source to near machine precision.
    let d = 3;
    let prob = dof::pde::swift_hohenberg(d, 0.3);
    let (w, phase, amp) = match &prob.exact {
        dof::pde::ExactSolution::SineWave { w, phase, amp } => (w.clone(), *phase, *amp),
        _ => unreachable!(),
    };
    let mut g = Graph::new();
    let xin = g.input(d);
    let lin = g.linear(xin, Tensor::from_vec(&[1, d], w), vec![phase]);
    let act = g.activation(lin, Act::Sin);
    g.linear(act, Tensor::from_vec(&[1, 1], vec![amp]), vec![0.0]);
    let x = Tensor::rand_uniform(&[5, d], 0.0, 1.0, &mut Xoshiro256::new(3107));
    let res = prob.operator.jet_engine().compute(&g, &x);
    let f = prob.source_batch(&x);
    for b in 0..5 {
        let got = res.operator_values.at(b, 0);
        let want = f.at(b, 0);
        assert!(
            (got - want).abs() < 1e-9 * want.abs().max(1.0),
            "row {b}: L_SH[u*] {got} vs manufactured f {want}"
        );
    }
}

fn assert_jet_bit_identical(a: &JetResult, b: &JetResult, what: &str) {
    assert_eq!(a.values, b.values, "{what}: values differ");
    assert_eq!(
        a.operator_values, b.operator_values,
        "{what}: L[φ] differs"
    );
    assert_eq!(a.out_jet.data, b.out_jet.data, "{what}: output jet differs");
    assert_eq!(a.cost, b.cost, "{what}: FLOP counts differ");
    assert_eq!(
        a.peak_jet_bytes, b.peak_jet_bytes,
        "{what}: peak jet bytes differ"
    );
}

#[test]
fn planned_matches_interpreter_bitwise() {
    let mut rng = Xoshiro256::new(3108);
    let n = 4;
    let g = mlp_graph(&random_layers(&[n, 10, 10, 1], &mut rng), Act::Tanh);
    let x = Tensor::randn(&[6, n], &mut rng);
    let op = HigherOrderOperator::from_spec(HigherOrderSpec::SwiftHohenberg { d: n, r: 0.2 });
    let engine = op.jet_engine();
    let planned = engine.compute(&g, &x);
    let reference = engine.compute_with_arena(&g, &x, &mut TangentArena::new());
    assert_jet_bit_identical(&planned, &reference, "mlp swift-hohenberg");
}

#[test]
fn sharded_jet_bit_identical_across_thread_counts() {
    let mut rng = Xoshiro256::new(3109);
    let n = 4;
    // Awkward batch: short last shard exercises per-shard slab keying.
    let g = mlp_graph(&random_layers(&[n, 12, 1], &mut rng), Act::Tanh);
    let x = Tensor::randn(&[21, n], &mut rng).scale(0.5);
    let op = HigherOrderOperator::from_spec(HigherOrderSpec::Biharmonic { d: n });
    let engine = op.jet_engine();
    let program = engine.plan(&g);
    let shard_rows = 8usize;
    let reference = engine.compute_with_arena(&g, &x, &mut TangentArena::new());
    let base = engine.execute_sharded(&program, &g, &x, &Pool::new(1), shard_rows);
    // Per-row arithmetic is row-independent → sharded equals unsharded
    // bitwise; cost is exactly batch-linear; peak is per-shard.
    assert_eq!(base.values, reference.values);
    assert_eq!(base.operator_values, reference.operator_values);
    assert_eq!(base.out_jet.data, reference.out_jet.data);
    assert_eq!(base.cost, reference.cost);
    assert_eq!(
        base.peak_jet_bytes * 21,
        reference.peak_jet_bytes * shard_rows as u64,
        "per-shard peak must scale exactly with shard rows"
    );
    for threads in [2usize, 4, 8] {
        let r = engine.execute_sharded(&program, &g, &x, &Pool::new(threads), shard_rows);
        assert_jet_bit_identical(&r, &base, &format!("{threads} threads"));
    }
}

#[test]
fn program_analytics_match_execution_without_running() {
    let mut rng = Xoshiro256::new(3110);
    let n = 4;
    let g = mlp_graph(&random_layers(&[n, 9, 9, 1], &mut rng), Act::Tanh);
    let op = HigherOrderOperator::from_spec(HigherOrderSpec::Biharmonic { d: n });
    let engine = op.jet_engine();
    let program = engine.plan(&g);
    for batch in [1usize, 3, 8] {
        let x = Tensor::randn(&[batch, n], &mut rng);
        let run = engine.compute_with_arena(&g, &x, &mut TangentArena::new());
        assert_eq!(
            program.cost(batch),
            run.cost,
            "analytic cost must equal the interpreter's measured count"
        );
        assert_eq!(
            program.peak_jet_bytes(batch),
            run.peak_jet_bytes,
            "analytic peak must equal the interpreter's PeakTracker"
        );
    }
}

#[test]
fn one_program_many_batches_is_bit_stable() {
    // Compile once, execute fresh batches of varying sizes: each result
    // must equal a fresh interpreter run (no state leaks through reused
    // pool slabs between executions).
    let mut rng = Xoshiro256::new(3111);
    let blocks: Vec<_> = (0..2)
        .map(|_| random_layers(&[2, 6, 3], &mut rng))
        .collect();
    let g = sparse_mlp_graph(&blocks, Act::Sin);
    let op = HigherOrderOperator::from_spec(HigherOrderSpec::KuramotoSivashinsky { d: 4 });
    let engine = op.jet_engine();
    let program = engine.plan(&g);
    for i in 0..3 {
        let x = Tensor::randn(&[3 + i, 4], &mut rng).scale(0.4);
        let reused = engine.execute(&program, &g, &x);
        let fresh = engine.compute_with_arena(&g, &x, &mut TangentArena::new());
        assert_jet_bit_identical(&reused, &fresh, &format!("batch {i}"));
    }
}
