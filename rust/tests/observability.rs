//! Observability battery: the PR 8 contract that tracing and profiling are
//! **measurement, never perturbation**.
//!
//! * **Bitwise invisibility** — routed results with a tracer installed are
//!   bit-identical to untraced serving, across worker pools of 1/2/4/8
//!   threads (tracing composes with the PR 1 determinism contract).
//! * **Span-tree exactness** — under a scripted [`TickClock`] schedule a
//!   single routed request records exactly the documented tree
//!   (`request → attempt → queue_wait/batch_form → execute → shard*`) with
//!   exact ids, parents, ticks, labels, and detail payloads.
//! * **Drop accounting** — a single-shard ring under pressure retains
//!   exactly its capacity and counts every eviction.
//! * **Profiler ≡ analytic cost** — the per-step profiler's FLOP totals
//!   equal the compiled programs' `cost(batch)` for all three planned
//!   executors (DOF, Hessian baseline, jet), and profiled execution is
//!   bit-identical to unprofiled.
//! * **Dump round trip** — `Registry::to_json` → `parse_spans` reproduces
//!   the span log field-for-field and renders the identical tree.

use std::sync::Arc;
use std::time::Duration;

use dof::coordinator::{BatchPolicy, ModelServer, Router, RouterConfig, ServeConfig, TickClock};
use dof::graph::{builder::random_layers, mlp_graph, Act, Graph};
use dof::jet::program::{execute_jet, execute_jet_profiled};
use dof::jet::{biharmonic_terms, DirectionBasis, JetProgram};
use dof::obs::{parse_spans, render_tree, Registry, Span, SpanKind, StepProfiler, Tracer};
use dof::operators::{CoeffSpec, Operator};
use dof::parallel::Pool;
use dof::plan::exec::{execute_dof, execute_dof_profiled};
use dof::plan::hessian::{execute_hessian, execute_hessian_profiled, HessianPlan};
use dof::plan::pack_panels;
use dof::tensor::Tensor;
use dof::util::Xoshiro256;

/// Deterministic f32 request points for `(tag, iter)`.
fn points(tag: u64, iter: usize, rows: usize, width: usize) -> Vec<f32> {
    let mut rng = Xoshiro256::new(0x0B5 ^ tag.wrapping_mul(0x9E37_79B9) ^ iter as u64);
    (0..rows * width).map(|_| rng.normal() as f32).collect()
}

fn bits32(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

fn bits64(v: &[f64]) -> Vec<u64> {
    v.iter().map(|x| x.to_bits()).collect()
}

fn batch_input(rng: &mut Xoshiro256, rows: usize, n: usize) -> Tensor {
    Tensor::from_vec(&[rows, n], (0..rows * n).map(|_| rng.normal()).collect())
}

/// Route 6 requests of varying row counts through a one-replica DOF model
/// and return the bit patterns of every response. `tracer: None` is the
/// untraced baseline the traced runs must reproduce exactly.
fn run_traffic(
    graph: &Graph,
    op: &Operator,
    threads: usize,
    tracer: Option<Arc<Tracer>>,
) -> Vec<(Vec<u32>, Vec<u32>)> {
    let clock = TickClock::new();
    let mut router = Router::with_config(RouterConfig {
        clock: clock.clone(),
        tracer: tracer.clone(),
        ..RouterConfig::default()
    });
    router.register(
        "dof",
        ModelServer::spawn_dof_cfg(
            graph.clone(),
            op.dof_engine(),
            BatchPolicy {
                capacity: 8,
                max_wait: Duration::from_millis(1),
                max_wait_ticks: None,
            },
            Pool::new(threads),
            2,
            ServeConfig {
                clock: clock.clone(),
                tracer,
                ..ServeConfig::labeled("dof")
            },
        ),
    );
    let client = router.client("dof").unwrap();
    let n = graph.input_dim();
    let mut out = Vec::new();
    for it in 0..6 {
        let rows = 1 + it % 4;
        let resp = client.eval_blocking(points(1, it, rows, n)).unwrap();
        out.push((bits32(&resp.phi), bits32(&resp.lphi)));
        clock.advance(1);
    }
    router.shutdown();
    out
}

/// Tracing is bitwise-invisible: traced responses equal untraced ones bit
/// for bit, at every pool width, and all widths agree with each other.
#[test]
fn traced_serving_is_bitwise_identical_to_untraced_across_pool_widths() {
    let mut rng = Xoshiro256::new(0x0B5E);
    let n = 4;
    let graph = mlp_graph(&random_layers(&[n, 9, 1], &mut rng), Act::Tanh);
    let op = Operator::from_spec(CoeffSpec::EllipticGram { n, rank: n, seed: 51 });
    let mut baseline: Option<Vec<(Vec<u32>, Vec<u32>)>> = None;
    for threads in [1usize, 2, 4, 8] {
        let untraced = run_traffic(&graph, &op, threads, None);
        let tracer = Arc::new(Tracer::new());
        let traced = run_traffic(&graph, &op, threads, Some(Arc::clone(&tracer)));
        assert_eq!(
            untraced, traced,
            "tracer perturbed served bytes at pool width {threads}"
        );
        // The traced run actually recorded something (6 requests, each with
        // a full span chain) — invisibility is not vacuous.
        assert!(
            tracer.retained() >= 6 * 5,
            "traced run retained only {} spans",
            tracer.retained()
        );
        match &baseline {
            None => baseline = Some(untraced),
            Some(b) => assert_eq!(b, &untraced, "pool width {threads} diverged bitwise"),
        }
    }
}

/// One routed request under a scripted tick schedule records exactly the
/// documented span tree, with exact ids, parents, ticks, and details.
#[test]
fn span_tree_is_exact_under_a_scripted_tick_schedule() {
    let mut rng = Xoshiro256::new(0x7EE);
    let n = 3;
    let graph = mlp_graph(&random_layers(&[n, 6, 1], &mut rng), Act::Tanh);
    let op = Operator::from_spec(CoeffSpec::EllipticGram { n, rank: n, seed: 5 });
    let tracer = Arc::new(Tracer::with_shards(1, 1024));
    let clock = TickClock::new();
    // Scripted schedule: park the clock at tick 7 for the whole request —
    // every control-plane timestamp in the tree must read exactly 7.
    clock.advance(7);
    let mut router = Router::with_config(RouterConfig {
        clock: clock.clone(),
        tracer: Some(Arc::clone(&tracer)),
        ..RouterConfig::default()
    });
    router.register(
        "dof",
        ModelServer::spawn_dof_cfg(
            graph.clone(),
            op.dof_engine(),
            // Capacity-sized request: the 2-row submission cuts immediately,
            // max_wait never gates.
            BatchPolicy {
                capacity: 2,
                max_wait: Duration::from_secs(30),
                max_wait_ticks: None,
            },
            Pool::new(1),
            // shard_rows 1: the 2-row batch decomposes into exactly 2 shards.
            1,
            ServeConfig {
                clock: clock.clone(),
                tracer: Some(Arc::clone(&tracer)),
                ..ServeConfig::labeled("dof")
            },
        ),
    );
    let client = router.client("dof").unwrap();
    client.eval_blocking(points(2, 0, 2, n)).unwrap();
    router.shutdown();

    assert_eq!(tracer.dropped_spans(), 0);
    let spans = tracer.snapshot();
    assert_eq!(spans.len(), 7, "request/attempt/queue_wait/batch_form/execute/2×shard");

    // Ids are monotone from 1 in allocation order; the snapshot is id-sorted.
    let ids: Vec<u64> = spans.iter().map(|s| s.id).collect();
    assert_eq!(ids, vec![1, 2, 3, 4, 5, 6, 7]);
    let kinds: Vec<SpanKind> = spans.iter().map(|s| s.kind).collect();
    assert_eq!(
        kinds,
        vec![
            SpanKind::Request,
            SpanKind::Attempt,
            SpanKind::QueueWait,
            SpanKind::BatchForm,
            SpanKind::Execute,
            SpanKind::Shard,
            SpanKind::Shard,
        ]
    );
    // Tree shape: attempt under request, queue-wait and batch-form under
    // the attempt, execute under batch-form, shards under execute.
    let parents: Vec<u64> = spans.iter().map(|s| s.parent).collect();
    assert_eq!(parents, vec![0, 1, 2, 2, 4, 5, 5]);
    // Every span belongs to request 1 and reads the scripted tick exactly.
    for s in &spans {
        assert_eq!(s.request, 1, "span {} request id", s.id);
        assert_eq!((s.start_tick, s.end_tick), (7, 7), "span {} ticks", s.id);
        assert!(s.seconds >= 0.0, "span {} duration", s.id);
    }
    // Detail payloads: rows for request/queue_wait/batch_form/execute,
    // attempt ordinal for attempt, shard index for shards.
    let details: Vec<u64> = spans.iter().map(|s| s.detail).collect();
    assert_eq!(details, vec![2, 0, 2, 2, 2, 0, 1]);
    // Labels: model name at the root, replica index on the attempt, the
    // serve label everywhere below.
    assert_eq!(spans[0].label, "dof");
    assert_eq!(spans[1].label, "replica0");
    for s in &spans[2..] {
        assert_eq!(s.label, "dof", "span {} label", s.id);
    }
    // Batch formation is a pure control-plane marker: zero duration.
    assert_eq!(spans[3].seconds, 0.0);
    // The rendered tree carries the whole request.
    let tree = render_tree(&spans, Some(1));
    assert!(tree.contains("request 1"), "{tree}");
    for name in ["request", "attempt", "queue_wait", "batch_form", "execute", "shard"] {
        assert!(tree.contains(name), "tree missing {name}:\n{tree}");
    }
}

/// Ring pressure: a single-shard tracer with capacity 5 retains exactly 5
/// spans, counts every eviction, and keeps the latest activity.
#[test]
fn span_ring_drop_accounting_is_exact_under_pressure() {
    let mut rng = Xoshiro256::new(0xD40);
    let n = 3;
    let graph = mlp_graph(&random_layers(&[n, 5, 1], &mut rng), Act::Sin);
    let op = Operator::from_spec(CoeffSpec::EllipticGram { n, rank: n, seed: 9 });
    let tracer = Arc::new(Tracer::with_shards(1, 5));
    let clock = TickClock::new();
    let mut router = Router::with_config(RouterConfig {
        clock: clock.clone(),
        tracer: Some(Arc::clone(&tracer)),
        ..RouterConfig::default()
    });
    router.register(
        "dof",
        ModelServer::spawn_dof_cfg(
            graph.clone(),
            op.dof_engine(),
            BatchPolicy {
                capacity: 1,
                max_wait: Duration::from_secs(30),
                max_wait_ticks: None,
            },
            Pool::new(1),
            // shard_rows ≥ rows: every 1-row batch is exactly 1 shard, so
            // each request records exactly 6 spans.
            8,
            ServeConfig {
                clock: clock.clone(),
                tracer: Some(Arc::clone(&tracer)),
                ..ServeConfig::labeled("dof")
            },
        ),
    );
    let client = router.client("dof").unwrap();
    let requests = 8u64;
    for it in 0..requests as usize {
        client.eval_blocking(points(3, it, 1, n)).unwrap();
        clock.advance(1);
    }
    router.shutdown();

    // Serial traffic: span recording is strictly ordered, so the ring
    // arithmetic is exact — 6 spans per request, capacity 5 retained.
    let recorded = 6 * requests;
    assert_eq!(tracer.retained(), 5);
    assert_eq!(tracer.dropped_spans(), recorded - 5);
    // The survivors are all from the final request (root id 6·7 + 1 = 43):
    // eviction discards oldest-first.
    let last_root = 6 * (requests - 1) + 1;
    for s in tracer.snapshot() {
        assert_eq!(
            s.request, last_root,
            "retained span {} belongs to an evicted request",
            s.id
        );
    }
}

/// The per-step profiler's FLOP totals equal the compiled programs' exact
/// analytic `cost(batch)` for all three planned executors, and profiled
/// execution returns bit-identical results to unprofiled.
#[test]
fn profiler_flop_totals_equal_analytic_program_costs() {
    let mut rng = Xoshiro256::new(0x9F0F);
    let batch = 5usize;

    // DOF (order 2, planned slab executor).
    let n = 4;
    let graph = mlp_graph(&random_layers(&[n, 9, 1], &mut rng), Act::Tanh);
    let op = Operator::from_spec(CoeffSpec::EllipticGram { n, rank: n, seed: 77 });
    let eng = op.dof_engine();
    let program = eng.plan(&graph);
    let panels = pack_panels(program.steps(), &graph);
    let x = batch_input(&mut rng, batch, n);
    let mut slab = Vec::new();
    let plain = execute_dof(
        &program,
        &graph,
        &eng.ldl,
        eng.b.as_deref(),
        eng.c,
        &x,
        &panels,
        &mut slab,
    );
    let mut prof = StepProfiler::new();
    let mut slab2 = Vec::new();
    let profiled = execute_dof_profiled(
        &program,
        &graph,
        &eng.ldl,
        eng.b.as_deref(),
        eng.c,
        &x,
        &panels,
        &mut slab2,
        Some(&mut prof),
    );
    assert!(!prof.is_empty());
    let want = program.cost(batch);
    assert_eq!(prof.total_muls(), want.muls, "DOF profiler muls");
    assert_eq!(prof.total_adds(), want.adds, "DOF profiler adds");
    assert_eq!(profiled.cost, want, "DOF executed cost");
    assert!(prof.total_seconds() >= 0.0);
    assert_eq!(
        bits64(plain.values.data()),
        bits64(profiled.values.data()),
        "DOF profiling perturbed φ"
    );
    assert_eq!(
        bits64(plain.operator_values.data()),
        bits64(profiled.operator_values.data()),
        "DOF profiling perturbed L[φ]"
    );

    // Hessian baseline (planned reverse-over-forward).
    let heng = op.hessian_engine();
    let hplan = HessianPlan::compile(&graph);
    let hpanels = pack_panels(hplan.steps(), &graph);
    let mut hslab = Vec::new();
    let hplain = execute_hessian(
        &hplan,
        &graph,
        &heng.a,
        heng.b.as_deref(),
        heng.c,
        &x,
        &hpanels,
        &mut hslab,
    );
    let mut hprof = StepProfiler::new();
    let mut hslab2 = Vec::new();
    let hprofiled = execute_hessian_profiled(
        &hplan,
        &graph,
        &heng.a,
        heng.b.as_deref(),
        heng.c,
        &x,
        &hpanels,
        &mut hslab2,
        Some(&mut hprof),
    );
    assert!(!hprof.is_empty());
    let hwant = hplan.cost(batch, heng.b.is_some(), heng.c.is_some());
    assert_eq!(hprof.total_muls(), hwant.muls, "Hessian profiler muls");
    assert_eq!(hprof.total_adds(), hwant.adds, "Hessian profiler adds");
    assert_eq!(hprofiled.cost, hwant, "Hessian executed cost");
    assert_eq!(
        bits64(hplain.operator_values.data()),
        bits64(hprofiled.operator_values.data()),
        "Hessian profiling perturbed L[φ]"
    );

    // Jet (order-4 biharmonic Taylor-mode).
    let d = 3;
    let jgraph = mlp_graph(&random_layers(&[d, 7, 1], &mut rng), Act::Tanh);
    let basis = DirectionBasis::from_terms(d, &biharmonic_terms(d, 1.0), None);
    let jprogram = JetProgram::compile(&jgraph, &basis, false);
    let jpanels = pack_panels(jprogram.steps(), &jgraph);
    let xj = batch_input(&mut rng, batch, d);
    let mut jslab = Vec::new();
    let jplain = execute_jet(&jprogram, &jgraph, &basis, None, &xj, &jpanels, &mut jslab);
    let mut jprof = StepProfiler::new();
    let mut jslab2 = Vec::new();
    let jprofiled = execute_jet_profiled(
        &jprogram,
        &jgraph,
        &basis,
        None,
        &xj,
        &jpanels,
        &mut jslab2,
        Some(&mut jprof),
    );
    assert!(!jprof.is_empty());
    let jwant = jprogram.cost(batch);
    assert_eq!(jprof.total_muls(), jwant.muls, "jet profiler muls");
    assert_eq!(jprof.total_adds(), jwant.adds, "jet profiler adds");
    assert_eq!(jprofiled.cost, jwant, "jet executed cost");
    assert_eq!(
        bits64(jplain.operator_values.data()),
        bits64(jprofiled.operator_values.data()),
        "jet profiling perturbed L[φ]"
    );

    // The efficiency table renders every step plus the total row.
    let table = prof.render_table("dof");
    assert!(table.lines().count() >= prof.records().len() + 2, "{table}");
}

/// `Registry::to_json` → `parse_spans` reproduces the span log field for
/// field (f64 seconds round-trip exactly through shortest-representation
/// formatting), and both sides render the identical tree.
#[test]
fn telemetry_dump_round_trips_the_span_tree() {
    let tracer = Tracer::with_shards(1, 64);
    let root = tracer.next_id();
    let attempt = tracer.next_id();
    let execute = tracer.next_id();
    for (id, parent, kind, label, seconds, detail) in [
        (root, 0, SpanKind::Request, "model \"a\"", 0.012_345_678_9, 4),
        (attempt, root, SpanKind::Attempt, "replica0", 0.011, 0),
        (execute, attempt, SpanKind::Execute, "dof", 0.009, 4),
    ] {
        tracer.record(Span {
            id,
            parent,
            request: root,
            kind,
            label: label.to_string(),
            start_tick: 3,
            end_tick: 5,
            seconds,
            detail,
        });
    }
    let mut reg = Registry::new();
    reg.set_spans(&tracer);
    let json = reg.to_json();
    assert!(json.contains("\"telemetry_schema\": 1"));

    let parsed = parse_spans(&json);
    let orig = tracer.snapshot();
    assert_eq!(parsed.len(), orig.len());
    for (a, b) in orig.iter().zip(&parsed) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.parent, b.parent);
        assert_eq!(a.request, b.request);
        assert_eq!(a.kind, b.kind);
        assert_eq!(a.label, b.label, "label survives JSON escaping");
        assert_eq!(a.start_tick, b.start_tick);
        assert_eq!(a.end_tick, b.end_tick);
        assert_eq!(a.seconds.to_bits(), b.seconds.to_bits(), "span {} seconds", a.id);
        assert_eq!(a.detail, b.detail);
    }
    assert_eq!(render_tree(&orig, None), render_tree(&parsed, None));
    assert!(render_tree(&parsed, Some(root)).contains("request 1"));
}
