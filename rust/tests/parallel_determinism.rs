//! The parallel subsystem's determinism contract, tested end to end:
//!
//! * sharded DOF / Hessian runs are **bit-identical** across 1/2/4/8
//!   threads — values, `L[φ]`, exact FLOP counts, and per-shard peak
//!   tangent bytes;
//! * sharded values match the unsharded engines exactly (per-row
//!   arithmetic never mixes rows);
//! * tangent-arena pooling changes allocator traffic only — the
//!   `PeakTracker` measurements (Theorem 2.2's `M₁`) are unchanged;
//! * per-shard peaks stay bounded by the analytic memory model.

use dof::autodiff::{DofEngine, HessianEngine, MemoryModel, TangentArena};
use dof::graph::{builder::random_layers, mlp_graph, sparse_mlp_graph, Act, Graph};
use dof::operators::CoeffSpec;
use dof::parallel::{Pool, DEFAULT_SHARD_ROWS};
use dof::tensor::Tensor;
use dof::util::Xoshiro256;

fn random_symmetric(n: usize, rng: &mut Xoshiro256) -> Tensor {
    let b = Tensor::randn(&[n, n], rng);
    b.add(&b.transpose()).scale(0.5)
}

fn mlp_fixture() -> (Graph, Tensor, Tensor) {
    let mut rng = Xoshiro256::new(2026);
    let g = mlp_graph(&random_layers(&[12, 48, 48, 48, 1], &mut rng), Act::Tanh);
    // Deliberately awkward batch: not a multiple of the shard size, so the
    // last shard is short and the GEMM remainder paths get exercised.
    let x = Tensor::randn(&[37, 12], &mut rng);
    let a = random_symmetric(12, &mut rng);
    (g, x, a)
}

#[test]
fn dof_bit_identical_across_thread_counts() {
    let (g, x, a) = mlp_fixture();
    let eng = DofEngine::new(&a);
    let base = eng.compute_sharded(&g, &x, &Pool::new(1), DEFAULT_SHARD_ROWS);
    for threads in [2usize, 4, 8] {
        let r = eng.compute_sharded(&g, &x, &Pool::new(threads), DEFAULT_SHARD_ROWS);
        assert_eq!(r.values, base.values, "values differ at {threads} threads");
        assert_eq!(
            r.operator_values, base.operator_values,
            "L[φ] differs at {threads} threads"
        );
        assert_eq!(r.cost, base.cost, "FLOP counts differ at {threads} threads");
        assert_eq!(
            r.peak_tangent_bytes, base.peak_tangent_bytes,
            "peak tangent bytes differ at {threads} threads"
        );
        assert_eq!(r.out_active, base.out_active);
        assert_eq!(r.out_tangent.data, base.out_tangent.data);
    }
}

#[test]
fn dof_sharded_matches_unsharded_engine() {
    let (g, x, a) = mlp_fixture();
    let eng = DofEngine::new(&a);
    let full = eng.compute(&g, &x);
    let sharded = eng.compute_sharded(&g, &x, &Pool::new(4), DEFAULT_SHARD_ROWS);
    // Per-row arithmetic is row-independent → exact equality, not tolerance.
    assert_eq!(sharded.values, full.values);
    assert_eq!(sharded.operator_values, full.operator_values);
    // Cost is exactly linear in batch rows on an MLP (no data-dependent
    // sparsity), so the shard sum reproduces the full-batch count.
    assert_eq!(sharded.cost, full.cost);
    // Peak is per shard: full-batch peak scales as batch/max_shard_rows.
    let batch = x.dims()[0] as u64;
    let max_shard = DEFAULT_SHARD_ROWS as u64;
    assert_eq!(
        sharded.peak_tangent_bytes * batch,
        full.peak_tangent_bytes * max_shard,
        "peak should scale exactly with shard rows"
    );
}

#[test]
fn dof_sharded_respects_theorem22_bound_per_shard() {
    let (g, x, a) = mlp_fixture();
    let eng = DofEngine::new(&a);
    let r = eng.compute_sharded(&g, &x, &Pool::new(4), DEFAULT_SHARD_ROWS);
    // The analytic forward-liveness peak (eq. 26) at the shard's batch size
    // bounds the measured per-shard peak.
    let model = MemoryModel::new(&g);
    let bound_bytes = model.forward_peak_scalars(eng.rank()) * 8 * DEFAULT_SHARD_ROWS as u64;
    assert!(
        r.peak_tangent_bytes <= bound_bytes,
        "per-shard peak {} exceeds the Theorem 2.2 model bound {}",
        r.peak_tangent_bytes,
        bound_bytes
    );
}

#[test]
fn dof_sparse_architecture_bit_identical_across_threads() {
    let mut rng = Xoshiro256::new(404);
    let blocks: Vec<_> = (0..4)
        .map(|_| random_layers(&[3, 10, 4], &mut rng))
        .collect();
    let g = sparse_mlp_graph(&blocks, Act::Tanh);
    let x = Tensor::randn(&[21, 12], &mut rng).scale(0.4);
    let a = CoeffSpec::BlockDiagGram {
        blocks: 4,
        block: 3,
        rank: 3,
        seed: 5,
    }
    .build();
    let eng = DofEngine::new(&a);
    let base = eng.compute_sharded(&g, &x, &Pool::new(1), 4);
    for threads in [2usize, 4, 8] {
        let r = eng.compute_sharded(&g, &x, &Pool::new(threads), 4);
        assert_eq!(r.operator_values, base.operator_values);
        assert_eq!(r.values, base.values);
        assert_eq!(r.cost, base.cost);
        assert_eq!(r.peak_tangent_bytes, base.peak_tangent_bytes);
    }
}

/// Satellite coverage: the sparse product-head architecture (`Op::Mul`)
/// through the **Hessian** baseline — its eq. 14 reverse sweep has
/// dedicated Mul handling that the plain-MLP fixture never touches.
#[test]
fn hessian_sparse_architecture_bit_identical_across_threads() {
    let mut rng = Xoshiro256::new(405);
    let blocks: Vec<_> = (0..3)
        .map(|_| random_layers(&[2, 8, 3], &mut rng))
        .collect();
    let g = sparse_mlp_graph(&blocks, Act::Tanh);
    let x = Tensor::randn(&[13, 6], &mut rng).scale(0.4);
    let a = CoeffSpec::BlockDiagGram {
        blocks: 3,
        block: 2,
        rank: 2,
        seed: 8,
    }
    .build();
    let eng = HessianEngine::new(&a);
    let full = eng.compute(&g, &x);
    let base = eng.compute_sharded(&g, &x, &Pool::new(1), 4);
    assert_eq!(base.values, full.values);
    assert_eq!(base.operator_values, full.operator_values);
    assert_eq!(base.hessian, full.hessian);
    assert_eq!(base.cost, full.cost);
    for threads in [2usize, 4, 8] {
        let r = eng.compute_sharded(&g, &x, &Pool::new(threads), 4);
        assert_eq!(r.values, base.values);
        assert_eq!(r.operator_values, base.operator_values);
        assert_eq!(r.hessian, base.hessian);
        assert_eq!(r.cost, base.cost);
        assert_eq!(r.peak_tangent_bytes, base.peak_tangent_bytes);
    }
}

/// Satellite coverage: operators with lower-order `(b, c)` terms — the
/// `b`-seeded scalar stream and the output `c·φ` correction must survive
/// sharding bit-identically on both engines, and the engines must still
/// agree with each other.
#[test]
fn lower_order_terms_bit_identical_across_threads_both_engines() {
    let mut rng = Xoshiro256::new(406);
    let g = mlp_graph(&random_layers(&[7, 20, 20, 1], &mut rng), Act::Sin);
    let x = Tensor::randn(&[19, 7], &mut rng);
    let a = random_symmetric(7, &mut rng);
    let bvec: Vec<f64> = (0..7).map(|_| rng.normal()).collect();
    let c = 1.3;
    let dof_eng = DofEngine::new(&a).with_lower_order(Some(bvec.clone()), Some(c));
    let hes_eng = HessianEngine::new(&a).with_lower_order(Some(bvec), Some(c));

    let dof_base = dof_eng.compute_sharded(&g, &x, &Pool::new(1), DEFAULT_SHARD_ROWS);
    let hes_base = hes_eng.compute_sharded(&g, &x, &Pool::new(1), DEFAULT_SHARD_ROWS);
    for threads in [2usize, 4, 8] {
        let d = dof_eng.compute_sharded(&g, &x, &Pool::new(threads), DEFAULT_SHARD_ROWS);
        assert_eq!(d.values, dof_base.values, "DOF values at {threads} threads");
        assert_eq!(d.operator_values, dof_base.operator_values);
        assert_eq!(d.cost, dof_base.cost);
        assert_eq!(d.peak_tangent_bytes, dof_base.peak_tangent_bytes);
        let h = hes_eng.compute_sharded(&g, &x, &Pool::new(threads), DEFAULT_SHARD_ROWS);
        assert_eq!(h.operator_values, hes_base.operator_values);
        assert_eq!(h.cost, hes_base.cost);
    }
    // The two exact methods agree on the full operator (2nd + 1st + 0th).
    for b in 0..x.dims()[0] {
        let dv = dof_base.operator_values.at(b, 0);
        let hv = hes_base.operator_values.at(b, 0);
        assert!(
            (dv - hv).abs() < 1e-8 * hv.abs().max(1.0),
            "b={b}: DOF {dv} vs Hessian {hv}"
        );
    }
}

/// Satellite coverage: lower-order terms on the sparse (`Op::Mul`)
/// architecture — the union-aligned scalar stream at the product head.
#[test]
fn lower_order_terms_sparse_architecture_across_threads() {
    let mut rng = Xoshiro256::new(407);
    let blocks: Vec<_> = (0..4)
        .map(|_| random_layers(&[3, 9, 4], &mut rng))
        .collect();
    let g = sparse_mlp_graph(&blocks, Act::Tanh);
    let x = Tensor::randn(&[11, 12], &mut rng).scale(0.4);
    let a = CoeffSpec::BlockDiagGram {
        blocks: 4,
        block: 3,
        rank: 3,
        seed: 6,
    }
    .build();
    let bvec: Vec<f64> = (0..12).map(|_| 0.3 * rng.normal()).collect();
    let eng = DofEngine::new(&a).with_lower_order(Some(bvec.clone()), Some(-0.4));
    let base = eng.compute_sharded(&g, &x, &Pool::new(1), 4);
    for threads in [2usize, 4, 8] {
        let r = eng.compute_sharded(&g, &x, &Pool::new(threads), 4);
        assert_eq!(r.operator_values, base.operator_values);
        assert_eq!(r.values, base.values);
        assert_eq!(r.cost, base.cost);
        assert_eq!(r.peak_tangent_bytes, base.peak_tangent_bytes);
    }
    let hes = HessianEngine::new(&a)
        .with_lower_order(Some(bvec), Some(-0.4))
        .compute_sharded(&g, &x, &Pool::new(4), 4);
    for b in 0..11 {
        let dv = base.operator_values.at(b, 0);
        let hv = hes.operator_values.at(b, 0);
        assert!(
            (dv - hv).abs() < 1e-8 * hv.abs().max(1.0),
            "b={b}: DOF {dv} vs Hessian {hv}"
        );
    }
}

#[test]
fn hessian_bit_identical_across_thread_counts_and_matches_unsharded() {
    let (g, x, a) = mlp_fixture();
    let eng = HessianEngine::new(&a);
    let full = eng.compute(&g, &x);
    let base = eng.compute_sharded(&g, &x, &Pool::new(1), DEFAULT_SHARD_ROWS);
    assert_eq!(base.values, full.values);
    assert_eq!(base.operator_values, full.operator_values);
    assert_eq!(base.gradient, full.gradient);
    assert_eq!(base.hessian, full.hessian);
    assert_eq!(base.cost, full.cost);
    for threads in [2usize, 4, 8] {
        let r = eng.compute_sharded(&g, &x, &Pool::new(threads), DEFAULT_SHARD_ROWS);
        assert_eq!(r.values, base.values);
        assert_eq!(r.operator_values, base.operator_values);
        assert_eq!(r.gradient, base.gradient);
        assert_eq!(r.hessian, base.hessian);
        assert_eq!(r.cost, base.cost);
        assert_eq!(r.peak_tangent_bytes, base.peak_tangent_bytes);
    }
}

#[test]
fn dof_and_hessian_still_agree_under_sharding() {
    let (g, x, a) = mlp_fixture();
    let dof = DofEngine::new(&a).compute_sharded(&g, &x, &Pool::new(4), DEFAULT_SHARD_ROWS);
    let hes = HessianEngine::new(&a).compute_sharded(&g, &x, &Pool::new(4), DEFAULT_SHARD_ROWS);
    for b in 0..x.dims()[0] {
        let dv = dof.operator_values.at(b, 0);
        let hv = hes.operator_values.at(b, 0);
        assert!(
            (dv - hv).abs() < 1e-8 * hv.abs().max(1.0),
            "b={b}: DOF {dv} vs Hessian {hv}"
        );
    }
}

#[test]
fn arena_reuse_leaves_results_and_peaks_unchanged() {
    let (g, x, a) = mlp_fixture();
    let eng = DofEngine::new(&a);
    let fresh = eng.compute(&g, &x);

    let mut arena = TangentArena::new();
    let r1 = eng.compute_with_arena(&g, &x, &mut arena);
    let after_first = arena.stats();
    assert!(after_first.recycled > 0, "liveness frees should park buffers");

    let r2 = eng.compute_with_arena(&g, &x, &mut arena);
    let after_second = arena.stats();

    // Pooling is invisible to results and to the Theorem 2.2 measurement.
    assert_eq!(r1.values, fresh.values);
    assert_eq!(r1.operator_values, fresh.operator_values);
    assert_eq!(r2.values, fresh.values);
    assert_eq!(r2.operator_values, fresh.operator_values);
    assert_eq!(r1.peak_tangent_bytes, fresh.peak_tangent_bytes);
    assert_eq!(r2.peak_tangent_bytes, fresh.peak_tangent_bytes);
    assert_eq!(r1.cost, fresh.cost);
    assert_eq!(r2.cost, fresh.cost);

    // The second pass is served from the pool: it adds hits, and adds no
    // more misses than the handful of result buffers that left the arena.
    assert!(
        after_second.hits > after_first.hits,
        "second run should reuse parked buffers ({after_first:?} → {after_second:?})"
    );
    let second_misses = after_second.misses - after_first.misses;
    assert!(
        second_misses <= 4,
        "steady-state pass should be ~allocation-free, got {second_misses} misses"
    );
}

/// The program-scheduled Hessian path (PR 4): bit-identical to the
/// retained reference walk — values, gradient, Hessian, `L[φ]`, and the
/// analytic FLOP/peak replay vs the reference's measured counters — and
/// bit-identical across 1/2/4/8 threads with batch-only per-shard peaks.
#[test]
fn hessian_program_path_matches_reference_and_is_thread_invariant() {
    let (g, x, a) = mlp_fixture();
    let eng = HessianEngine::new(&a);
    let reference = eng.compute_reference(&g, &x);
    let planned = eng.compute(&g, &x);
    assert_eq!(planned.values, reference.values);
    assert_eq!(planned.gradient, reference.gradient);
    assert_eq!(planned.hessian, reference.hessian);
    assert_eq!(planned.operator_values, reference.operator_values);
    assert_eq!(
        planned.cost, reference.cost,
        "analytic FLOPs must equal the reference's measured count"
    );
    assert_eq!(
        planned.peak_tangent_bytes, reference.peak_tangent_bytes,
        "analytic peak must equal the reference's PeakTracker"
    );

    let shard_rows = DEFAULT_SHARD_ROWS;
    let base = eng.compute_sharded(&g, &x, &Pool::new(1), shard_rows);
    // Per-shard peak is exactly batch-linear (analytic replay), so the
    // full-batch and max-shard peaks relate by their row counts.
    let batch = x.dims()[0] as u64;
    assert_eq!(
        base.peak_tangent_bytes * batch,
        planned.peak_tangent_bytes * shard_rows as u64,
        "per-shard peak must scale exactly with shard rows"
    );
    for threads in [2usize, 4, 8] {
        let r = eng.compute_sharded(&g, &x, &Pool::new(threads), shard_rows);
        assert_eq!(r.values, base.values);
        assert_eq!(r.gradient, base.gradient);
        assert_eq!(r.hessian, base.hessian);
        assert_eq!(r.operator_values, base.operator_values);
        assert_eq!(r.cost, base.cost);
        assert_eq!(r.peak_tangent_bytes, base.peak_tangent_bytes);
    }
}

/// The baseline on a DOF-compiled program (`compute_sharded_with_program`)
/// must equal the standalone planned path exactly — the bench harness's
/// steady-state shape.
#[test]
fn hessian_with_program_equals_standalone_planned_path() {
    let (g, x, a) = mlp_fixture();
    let dof_eng = DofEngine::new(&a);
    let program = dof_eng.plan(&g);
    let hes = HessianEngine::new(&a);
    let pool = Pool::new(4);
    let via_program =
        hes.compute_sharded_with_program(&program, &g, &x, &pool, DEFAULT_SHARD_ROWS);
    let standalone = hes.compute_sharded(&g, &x, &pool, DEFAULT_SHARD_ROWS);
    assert_eq!(via_program.values, standalone.values);
    assert_eq!(via_program.operator_values, standalone.operator_values);
    assert_eq!(via_program.hessian, standalone.hessian);
    assert_eq!(via_program.cost, standalone.cost);
    assert_eq!(via_program.peak_tangent_bytes, standalone.peak_tangent_bytes);
}

/// Wall-clock sanity for the tentpole claim (ignored by default: timing
/// asserts are machine-dependent; run with `cargo test -- --ignored`).
#[test]
#[ignore]
fn parallel_speedup_at_large_batch() {
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    if cores < 8 {
        eprintln!("skipping: only {cores} cores");
        return;
    }
    let mut rng = Xoshiro256::new(7);
    let g = mlp_graph(&random_layers(&[64, 256, 256, 256, 256, 1], &mut rng), Act::Tanh);
    let x = Tensor::randn(&[256, 64], &mut rng);
    let a = random_symmetric(64, &mut rng);
    let eng = DofEngine::new(&a);
    let time = |pool: &Pool| {
        // Warm the per-thread arenas, then take the best of 3.
        eng.compute_sharded(&g, &x, pool, DEFAULT_SHARD_ROWS);
        (0..3)
            .map(|_| {
                let t0 = std::time::Instant::now();
                std::hint::black_box(eng.compute_sharded(&g, &x, pool, DEFAULT_SHARD_ROWS));
                t0.elapsed().as_secs_f64()
            })
            .fold(f64::INFINITY, f64::min)
    };
    let t1 = time(&Pool::new(1));
    let t8 = time(&Pool::new(8));
    let speedup = t1 / t8.max(1e-12);
    eprintln!("batch 256: 1 thread {t1:.4}s, 8 threads {t8:.4}s → {speedup:.2}×");
    assert!(speedup >= 3.0, "expected ≥3× speedup, got {speedup:.2}×");
}
