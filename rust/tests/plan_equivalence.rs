//! The plan subsystem's contract, tested differentially: **planned
//! execution is bit-identical to the pre-refactor interpreter path** —
//! values, `L[φ]`, the output tangent, exact FLOP counts, and peak tangent
//! bytes — across architectures (plain MLP, sparse `Op::Mul`
//! product-head), operator classes (dense symmetric, block-diagonal,
//! low-rank, lower-order `(b, c)` terms), sparsity on/off, and 1/2/4/8
//! threads. The interpreter (`DofEngine::compute_with_arena`) is retained
//! in-tree precisely to serve as this oracle.

use dof::autodiff::{DofEngine, DofResult, TangentArena};
use dof::graph::{builder::random_layers, mlp_graph, sparse_mlp_graph, Act, Graph};
use dof::operators::CoeffSpec;
use dof::parallel::Pool;
use dof::tensor::Tensor;
use dof::util::Xoshiro256;

fn random_symmetric(n: usize, rng: &mut Xoshiro256) -> Tensor {
    let b = Tensor::randn(&[n, n], rng);
    b.add(&b.transpose()).scale(0.5)
}

/// Bitwise equality of every observable field.
fn assert_bit_identical(planned: &DofResult, reference: &DofResult, what: &str) {
    assert_eq!(planned.values, reference.values, "{what}: values differ");
    assert_eq!(
        planned.operator_values, reference.operator_values,
        "{what}: L[φ] differs"
    );
    assert_eq!(
        planned.out_active, reference.out_active,
        "{what}: active output rows differ"
    );
    assert_eq!(
        planned.out_tangent.data, reference.out_tangent.data,
        "{what}: output tangent differs"
    );
    assert_eq!(planned.cost, reference.cost, "{what}: FLOP counts differ");
    assert_eq!(
        planned.peak_tangent_bytes, reference.peak_tangent_bytes,
        "{what}: peak tangent bytes differ"
    );
}

fn interpreter(eng: &DofEngine, g: &Graph, x: &Tensor) -> DofResult {
    eng.compute_with_arena(g, x, &mut TangentArena::new())
}

#[test]
fn planned_matches_interpreter_mlp_bitwise() {
    let mut rng = Xoshiro256::new(2101);
    let g = mlp_graph(&random_layers(&[10, 32, 32, 1], &mut rng), Act::Tanh);
    let x = Tensor::randn(&[9, 10], &mut rng);
    let a = random_symmetric(10, &mut rng);
    let eng = DofEngine::new(&a);
    assert_bit_identical(&eng.compute(&g, &x), &interpreter(&eng, &g, &x), "mlp");
}

#[test]
fn planned_matches_interpreter_sparse_architecture_bitwise() {
    let mut rng = Xoshiro256::new(2102);
    let blocks: Vec<_> = (0..4)
        .map(|_| random_layers(&[3, 12, 5], &mut rng))
        .collect();
    let g = sparse_mlp_graph(&blocks, Act::Tanh);
    let x = Tensor::randn(&[7, 12], &mut rng).scale(0.4);
    let a = CoeffSpec::BlockDiagGram {
        blocks: 4,
        block: 3,
        rank: 3,
        seed: 5,
    }
    .build();
    // Sparsity on (the §3.2 path: pruned slices, unions at Mul/Concat)…
    let sparse = DofEngine::new(&a);
    assert_bit_identical(
        &sparse.compute(&g, &x),
        &interpreter(&sparse, &g, &x),
        "sparse arch, §3.2 on",
    );
    // …and off (full-width tangents everywhere).
    let dense = DofEngine::new(&a).dense();
    assert_bit_identical(
        &dense.compute(&g, &x),
        &interpreter(&dense, &g, &x),
        "sparse arch, §3.2 off",
    );
}

#[test]
fn planned_matches_interpreter_lower_order_and_low_rank() {
    let mut rng = Xoshiro256::new(2103);
    let g = mlp_graph(&random_layers(&[6, 14, 1], &mut rng), Act::Sin);
    let x = Tensor::randn(&[5, 6], &mut rng);
    // Low-rank second-order part (tangent width 2 < N).
    let bmat = Tensor::randn(&[6, 2], &mut rng);
    let a = dof::tensor::matmul(&bmat, &bmat.transpose());
    let bvec: Vec<f64> = (0..6).map(|_| rng.normal()).collect();
    let eng = DofEngine::new(&a).with_lower_order(Some(bvec), Some(-0.9));
    assert_eq!(eng.rank(), 2);
    assert_bit_identical(
        &eng.compute(&g, &x),
        &interpreter(&eng, &g, &x),
        "low-rank + (b, c)",
    );
}

#[test]
fn planned_sharded_matches_interpreter_across_thread_counts() {
    let mut rng = Xoshiro256::new(2104);
    let g = mlp_graph(&random_layers(&[8, 24, 24, 1], &mut rng), Act::Tanh);
    // Awkward batch: short last shard exercises per-shard slab sizing.
    let x = Tensor::randn(&[21, 8], &mut rng);
    let a = random_symmetric(8, &mut rng);
    let eng = DofEngine::new(&a);
    let reference = interpreter(&eng, &g, &x);
    let program = eng.plan(&g);
    let shard_rows = 8usize;
    let base = eng.execute_sharded(&program, &g, &x, &Pool::new(1), shard_rows);
    // Values are row-independent → sharded output equals the unsharded
    // interpreter bitwise; cost is exactly linear in rows → the shard sum
    // reproduces the full-batch count; peaks relate by the shard size.
    assert_eq!(base.values, reference.values);
    assert_eq!(base.operator_values, reference.operator_values);
    assert_eq!(base.cost, reference.cost);
    assert_eq!(
        base.peak_tangent_bytes * 21,
        reference.peak_tangent_bytes * shard_rows as u64,
        "per-shard peak must scale exactly with shard rows"
    );
    for threads in [2usize, 4, 8] {
        let r = eng.execute_sharded(&program, &g, &x, &Pool::new(threads), shard_rows);
        assert_eq!(r.values, base.values, "values differ at {threads} threads");
        assert_eq!(r.operator_values, base.operator_values);
        assert_eq!(r.out_tangent.data, base.out_tangent.data);
        assert_eq!(r.cost, base.cost);
        assert_eq!(r.peak_tangent_bytes, base.peak_tangent_bytes);
    }
}

#[test]
fn one_program_many_batches_is_bit_stable() {
    // Compile once, execute on several fresh batches: each result must be
    // identical to a freshly compiled run (no state leaks through the
    // reused slab between executions).
    let mut rng = Xoshiro256::new(2105);
    let blocks: Vec<_> = (0..3)
        .map(|_| random_layers(&[2, 8, 3], &mut rng))
        .collect();
    let g = sparse_mlp_graph(&blocks, Act::Gelu);
    let a = CoeffSpec::BlockDiagGram {
        blocks: 3,
        block: 2,
        rank: 2,
        seed: 9,
    }
    .build();
    let eng = DofEngine::new(&a);
    let program = eng.plan(&g);
    for i in 0..3 {
        let x = Tensor::randn(&[4 + i, 6], &mut rng).scale(0.5);
        let reused = eng.execute(&program, &g, &x);
        let fresh = interpreter(&eng, &g, &x);
        assert_bit_identical(&reused, &fresh, &format!("batch {i}"));
    }
}

#[test]
fn program_analytics_match_execution_without_running() {
    let mut rng = Xoshiro256::new(2106);
    let g = mlp_graph(&random_layers(&[5, 16, 16, 1], &mut rng), Act::Tanh);
    let a = random_symmetric(5, &mut rng);
    let eng = DofEngine::new(&a);
    let program = eng.plan(&g);
    for batch in [1usize, 3, 8] {
        let x = Tensor::randn(&[batch, 5], &mut rng);
        let run = interpreter(&eng, &g, &x);
        assert_eq!(
            program.cost(batch),
            run.cost,
            "analytic cost must equal the interpreter's measured count"
        );
        assert_eq!(
            program.peak_tangent_bytes(batch),
            run.peak_tangent_bytes,
            "analytic peak must equal the interpreter's PeakTracker"
        );
    }
}

#[test]
fn planned_tape_values_agree_with_engine_and_eval() {
    // The training tape runs the same program schedule (dense mode); its
    // value stream must match plain evaluation and its operator stream the
    // engine's L[φ] to numerical precision.
    let mut rng = Xoshiro256::new(2107);
    let g = mlp_graph(&random_layers(&[4, 10, 1], &mut rng), Act::Tanh);
    let x = Tensor::randn(&[6, 4], &mut rng);
    let a = random_symmetric(4, &mut rng);
    let ldl = dof::linalg::LdlDecomposition::of(&a);
    let tape = dof::autodiff::dof_tape::dof_forward_tape(&g, &ldl, None, &x);
    let eval = g.eval(&x);
    let eng = DofEngine::new(&a).dense();
    let res = eng.compute(&g, &x);
    let out = g.output();
    for b in 0..6 {
        assert!((tape.values[out].at(b, 0) - eval.at(b, 0)).abs() < 1e-12);
        assert!(
            (tape.scalars[out].at(b, 0) - res.operator_values.at(b, 0)).abs()
                < 1e-9 * res.operator_values.at(b, 0).abs().max(1.0),
            "tape L[φ] vs engine L[φ] at row {b}"
        );
    }
}
