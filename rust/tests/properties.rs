//! Property-based integration tests: random graphs × random operators,
//! checked against ground truth and the paper's theorems, via the in-repo
//! property-testing substrate ([`dof::prop`]).

use dof::autodiff::{CostModel, DofEngine, HessianEngine, MemoryModel};
use dof::graph::{builder::random_layers, mlp_graph, sparse_mlp_graph, Act, Graph};
use dof::linalg::LdlDecomposition;
use dof::prop::{close, run_prop, Gen};
use dof::tensor::{matmul, Tensor};

/// Random symmetric matrix with a controlled rank.
fn random_coeff(g: &mut Gen, n: usize) -> Tensor {
    let kind = g.usize_in(0, 2);
    match kind {
        0 => {
            // full-rank symmetric (possibly indefinite)
            let b = Tensor::randn(&[n, n], g.rng());
            b.add(&b.transpose()).scale(0.5)
        }
        1 => {
            // low-rank PSD
            let r = g.usize_in(1, n);
            let b = Tensor::randn(&[n, r], g.rng());
            matmul(&b, &b.transpose())
        }
        _ => {
            // signed diagonal
            let mut a = Tensor::eye(n);
            for i in 0..n {
                if g.bool_with(0.3) {
                    a.set(i, i, -1.0);
                }
            }
            a
        }
    }
}

/// Random small MLP graph.
fn random_mlp(g: &mut Gen, n: usize) -> Graph {
    let depth = g.usize_in(1, 3);
    let mut dims = vec![n];
    for _ in 0..depth {
        dims.push(g.usize_in(2, 12));
    }
    dims.push(1);
    let act = g.choice(&[Act::Tanh, Act::Sin, Act::Gelu, Act::Softplus]);
    mlp_graph(&random_layers(&dims, g.rng()), act)
}

#[test]
fn prop_dof_equals_hessian_on_random_mlps() {
    run_prop("dof == hessian (mlp)", 40, 101, |g| {
        let n = g.usize_in(2, 8);
        let graph = random_mlp(g, n);
        let a = random_coeff(g, n);
        let batch = g.usize_in(1, 3);
        let x = Tensor::randn(&[batch, n], g.rng());
        let dof = DofEngine::new(&a).compute(&graph, &x);
        let hes = HessianEngine::new(&a).compute(&graph, &x);
        for b in 0..batch {
            close(
                dof.operator_values.at(b, 0),
                hes.operator_values.at(b, 0),
                1e-7,
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_dof_equals_hessian_on_random_sparse_graphs() {
    run_prop("dof == hessian (sparse)", 20, 202, |g| {
        let k = g.usize_in(2, 4);
        let block_in = g.usize_in(1, 3);
        let out_dim = g.usize_in(1, 4);
        let hidden = g.usize_in(2, 8);
        let blocks: Vec<_> = (0..k)
            .map(|_| random_layers(&[block_in, hidden, out_dim], g.rng()))
            .collect();
        let graph = sparse_mlp_graph(&blocks, g.choice(&[Act::Tanh, Act::Sin]));
        let n = k * block_in;
        let a = random_coeff(g, n);
        let x = Tensor::randn(&[2, n], g.rng()).scale(0.5);
        let dof = DofEngine::new(&a).compute(&graph, &x);
        let hes = HessianEngine::new(&a).compute(&graph, &x);
        for b in 0..2 {
            close(
                dof.operator_values.at(b, 0),
                hes.operator_values.at(b, 0),
                1e-7,
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_theorem21_flops_on_random_architectures() {
    run_prop("theorem 2.1 (FLOPs ≤ ~half)", 25, 303, |g| {
        let n = g.usize_in(4, 10);
        let graph = random_mlp(g, n);
        let a = {
            let b = Tensor::randn(&[n, n], g.rng());
            b.add(&b.transpose()).scale(0.5)
        };
        let x = Tensor::randn(&[1, n], g.rng());
        let dof = DofEngine::new(&a).compute(&graph, &x);
        let hes = HessianEngine::new(&a).compute(&graph, &x);
        // Theorem 2.1 counts only the tangent sweeps; our engines also run
        // the value and s streams (+2 widths on the DOF side, +1 backward
        // sweep on the Hessian side), so the finite-N bound is
        // (N+2)/(2N+1) → ½ as N grows. Allow 10% slack for the nonlinear
        // |T|-terms of narrow random graphs.
        let bound = (n as f64 + 2.0) / (2.0 * n as f64 + 1.0) * 1.10;
        let ratio = dof.cost.muls as f64 / hes.cost.muls as f64;
        if ratio <= bound {
            Ok(())
        } else {
            Err(format!("DOF/Hessian mul ratio {ratio:.3} > bound {bound:.3}"))
        }
    });
}

#[test]
fn prop_theorem22_memory_on_random_architectures() {
    run_prop("theorem 2.2 (peak memory)", 25, 404, |g| {
        let n = g.usize_in(4, 10);
        let graph = random_mlp(g, n);
        let a = {
            let b = Tensor::randn(&[n, n], g.rng());
            b.add(&b.transpose()).scale(0.5)
        };
        let x = Tensor::randn(&[1, n], g.rng());
        let dof = DofEngine::new(&a).compute(&graph, &x);
        let hes = HessianEngine::new(&a).compute(&graph, &x);
        if dof.peak_tangent_bytes < hes.peak_tangent_bytes {
            Ok(())
        } else {
            Err(format!(
                "DOF peak {} !< Hessian peak {}",
                dof.peak_tangent_bytes, hes.peak_tangent_bytes
            ))
        }
    });
}

#[test]
fn prop_ldl_reconstruction_and_quadratic_form() {
    run_prop("A = LᵀDL", 60, 505, |g| {
        let n = g.usize_in(2, 12);
        let a = random_coeff(g, n);
        let dec = LdlDecomposition::of(&a);
        let sym = a.add(&a.transpose()).scale(0.5);
        let err = dec.reconstruct().max_abs_diff(&sym);
        if err > 1e-8 {
            return Err(format!("reconstruction error {err}"));
        }
        // Quadratic-form identity on random vectors.
        let x = Tensor::randn(&[n, 1], g.rng());
        let lx = matmul(&dec.l, &x);
        let q1 = dec.d_inner(lx.data(), lx.data());
        let ax = matmul(&sym, &x);
        let q2: f64 = x.data().iter().zip(ax.data()).map(|(&u, &v)| u * v).sum();
        close(q1, q2, 1e-8)
    });
}

#[test]
fn prop_memory_model_bounds_measured_peak() {
    // The analytic forward-peak model (eq. 25/26) must upper-bound the
    // engine's measured tangent bytes (per batch point, model counts only
    // tangent scalars; engine peak includes exactly those).
    run_prop("analytic C(j) ≥ measured", 20, 606, |g| {
        let n = g.usize_in(3, 8);
        let graph = random_mlp(g, n);
        let a = Tensor::eye(n);
        let x = Tensor::randn(&[1, n], g.rng());
        let dof = DofEngine::new(&a).dense().compute(&graph, &x);
        let model = MemoryModel::new(&graph).forward_peak_scalars(n) * 8;
        if dof.peak_tangent_bytes <= model {
            Ok(())
        } else {
            Err(format!(
                "measured {} > analytic bound {model}",
                dof.peak_tangent_bytes
            ))
        }
    });
}

#[test]
fn prop_analytic_cost_model_tracks_measured() {
    run_prop("analytic FLOPs ≈ measured", 20, 707, |g| {
        let n = g.usize_in(4, 8);
        // Wider layers so the model's ignored terms are relatively small.
        let dims = [n, 32, 32, 1];
        let graph = mlp_graph(&random_layers(&dims, g.rng()), Act::Tanh);
        let a = {
            let b = Tensor::randn(&[n, n], g.rng());
            b.add(&b.transpose()).scale(0.5)
        };
        let x = Tensor::randn(&[1, n], g.rng());
        let dof = DofEngine::new(&a).compute(&graph, &x);
        let model = CostModel::new(&graph, n);
        let ratio = dof.cost.muls as f64 / model.dof_muls() as f64;
        if (0.7..1.6).contains(&ratio) {
            Ok(())
        } else {
            Err(format!("measured/analytic = {ratio:.3}"))
        }
    });
}
