//! Differential battery for the multi-model serving [`Router`]:
//!
//! * **Routing is arithmetic-free** — mixed DOF / Hessian-baseline / jet
//!   traffic routed through the router returns **bitwise-identical** f32
//!   results to calling each engine directly (same f32→f64→f32 casts, same
//!   cached compiled programs; batching composition cannot matter because
//!   per-row arithmetic never mixes rows).
//! * **Metrics are exact** — dispatched/completed counters equal the
//!   number of requests sent per model, queue depth returns to zero, and
//!   the per-model server snapshots account for every request.
//! * **Shutdown drains** — requests parked in a worker's batcher when
//!   shutdown is requested are flushed and answered; no request is lost.
//!
//! `DOF_ROUTER_REQUESTS` scales the per-model traffic (the weekly
//! `fuzz-extended` CI job runs a soak-sized count).

use std::sync::Arc;
use std::time::Duration;

use dof::autodiff::{DofEngine, HessianEngine};
use dof::coordinator::{BatchPolicy, ModelServer, Router, RouterClient};
use dof::graph::{builder::random_layers, mlp_graph, Act, Graph};
use dof::jet::JetEngine;
use dof::operators::{CoeffSpec, HigherOrderOperator, HigherOrderSpec, Operator};
use dof::parallel::Pool;
use dof::tensor::Tensor;
use dof::util::Xoshiro256;

fn requests_per_model() -> usize {
    std::env::var("DOF_ROUTER_REQUESTS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(24)
}

fn policy() -> BatchPolicy {
    BatchPolicy {
        capacity: 8,
        max_wait: Duration::from_millis(1),
    }
}

/// Deterministic f32 request points for `(model_tag, client, iter)`.
fn points(model_tag: u64, client: usize, iter: usize, rows: usize, width: usize) -> Vec<f32> {
    let mut rng = Xoshiro256::new(
        0xB00 ^ model_tag.wrapping_mul(0x9E37_79B9) ^ ((client as u64) << 32) ^ iter as u64,
    );
    (0..rows * width).map(|_| rng.normal() as f32).collect()
}

/// The serving cast: f32 points → f64 tensor (exact), engine output → f32.
fn to_tensor(pts: &[f32], rows: usize, width: usize) -> Tensor {
    Tensor::from_vec(
        &[rows, width],
        pts.iter().map(|&v| v as f64).collect::<Vec<f64>>(),
    )
}

fn cast32(t: &Tensor) -> Vec<f32> {
    t.data().iter().map(|&v| v as f32).collect()
}

/// Direct (router-free) expectation for one request against one engine.
enum Direct {
    Dof(Operator, Graph),
    Hessian(Operator, Graph),
    Jet(HigherOrderOperator, Graph),
}

impl Direct {
    fn expect(&self, pts: &[f32], rows: usize, width: usize) -> (Vec<f32>, Vec<f32>) {
        let x = to_tensor(pts, rows, width);
        match self {
            Direct::Dof(op, g) => {
                let r = op.dof_engine().compute(g, &x);
                (cast32(&r.values), cast32(&r.operator_values))
            }
            Direct::Hessian(op, g) => {
                let r = op.hessian_engine().compute(g, &x);
                (cast32(&r.values), cast32(&r.operator_values))
            }
            Direct::Jet(op, g) => {
                let r = op.jet_engine().compute(g, &x);
                (cast32(&r.values), cast32(&r.operator_values))
            }
        }
    }
}

#[test]
fn mixed_traffic_bitwise_equals_direct_engine_calls() {
    let mut rng = Xoshiro256::new(0x5EED);

    // DOF model.
    let n_dof = 4;
    let g_dof = mlp_graph(&random_layers(&[n_dof, 9, 1], &mut rng), Act::Tanh);
    let op_dof = Operator::from_spec(CoeffSpec::EllipticGram {
        n: n_dof,
        rank: n_dof,
        seed: 21,
    });
    // Hessian-baseline model (its own graph — mixed models, mixed widths).
    let n_hes = 5;
    let g_hes = mlp_graph(&random_layers(&[n_hes, 8, 1], &mut rng), Act::Sin);
    let op_hes = Operator::from_spec(CoeffSpec::EllipticGram {
        n: n_hes,
        rank: n_hes,
        seed: 22,
    });
    // Jet model (order-4 biharmonic).
    let n_jet = 3;
    let g_jet = mlp_graph(&random_layers(&[n_jet, 7, 1], &mut rng), Act::Tanh);
    let op_jet = HigherOrderOperator::from_spec(HigherOrderSpec::Biharmonic { d: n_jet });

    let mut router = Router::new();
    router.register(
        "dof",
        ModelServer::spawn_dof(g_dof.clone(), op_dof.dof_engine(), policy(), Pool::new(2), 2),
    );
    router.register(
        "hessian",
        ModelServer::spawn_hessian(
            g_hes.clone(),
            op_hes.hessian_engine(),
            policy(),
            Pool::new(2),
            2,
        ),
    );
    router.register(
        "jet",
        ModelServer::spawn_jet(g_jet.clone(), op_jet.jet_engine(), policy(), Pool::new(2), 2),
    );

    let models: Vec<(u64, RouterClient, Arc<Direct>)> = vec![
        (1, router.client("dof").unwrap(), Arc::new(Direct::Dof(op_dof, g_dof))),
        (
            2,
            router.client("hessian").unwrap(),
            Arc::new(Direct::Hessian(op_hes, g_hes)),
        ),
        (3, router.client("jet").unwrap(), Arc::new(Direct::Jet(op_jet, g_jet))),
    ];

    // Mixed traffic: 3 client threads per model, interleaved submissions,
    // varying request sizes (1..=4 rows, crossing batch boundaries).
    let clients_per_model = 3;
    let per_client = (requests_per_model() / clients_per_model).max(1);
    let mut joins = Vec::new();
    for (tag, client, direct) in &models {
        for c in 0..clients_per_model {
            let tag = *tag;
            let client = client.clone();
            let direct = Arc::clone(direct);
            joins.push(std::thread::spawn(move || {
                let width = client.width();
                for it in 0..per_client {
                    let rows = 1 + (it + c) % 4;
                    let pts = points(tag, c, it, rows, width);
                    let resp = client.eval_blocking(pts.clone()).unwrap();
                    let (want_phi, want_lphi) = direct.expect(&pts, rows, width);
                    assert_eq!(resp.phi, want_phi, "model {tag} phi (bitwise)");
                    assert_eq!(resp.lphi, want_lphi, "model {tag} L[φ] (bitwise)");
                }
            }));
        }
    }
    for j in joins {
        j.join().expect("client thread panicked");
    }

    // Exact metrics: every model saw exactly clients_per_model × per_client
    // dispatches, all completed, none failed, queue drained.
    let sent = (clients_per_model * per_client) as u64;
    for m in router.snapshot() {
        assert_eq!(m.dispatched, sent, "model {} dispatched", m.model);
        assert_eq!(m.completed, sent, "model {} completed", m.model);
        assert_eq!(m.failed, 0, "model {} failed", m.model);
        assert_eq!(m.queue_depth, 0, "model {} queue drained", m.model);
        assert!(m.peak_queue_depth >= 1, "model {} saw traffic", m.model);
        assert_eq!(m.server.requests, sent, "model {} server requests", m.model);
    }
    router.shutdown();
}

#[test]
fn shutdown_drains_queued_requests_without_loss() {
    // A long max_wait parks requests in the batcher until shutdown cuts
    // the partial batch — the drain path under test.
    let mut rng = Xoshiro256::new(0xD3A1);
    let n = 3;
    let graph = mlp_graph(&random_layers(&[n, 6, 1], &mut rng), Act::Tanh);
    let op = Operator::from_spec(CoeffSpec::EllipticGram { n, rank: n, seed: 7 });
    let mut router = Router::new();
    router.register(
        "dof",
        ModelServer::spawn_dof(
            graph.clone(),
            op.dof_engine(),
            BatchPolicy {
                capacity: 64,
                max_wait: Duration::from_secs(30),
            },
            Pool::new(2),
            2,
        ),
    );
    let client = router.client("dof").unwrap();
    let direct = Direct::Dof(op, graph);
    let joins: Vec<_> = (0..4)
        .map(|c| {
            let client = client.clone();
            std::thread::spawn(move || {
                let width = client.width();
                let pts = points(9, c, 0, 2, width);
                let resp = client.eval_blocking(pts.clone()).unwrap();
                (c, pts, resp)
            })
        })
        .collect();
    // Wait until the worker has *received* all four requests (the
    // race-free arrival counter: a request is counted after it is pulled
    // off the channel, so Shutdown — sent strictly afterwards — cannot
    // overtake any of them). They cannot complete on their own: capacity
    // 64 is never filled and the deadline is 30 s away. Bounded wait: a
    // lost request must fail loudly here, not hang CI.
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    loop {
        let received = router.snapshot()[0].server.received;
        if received >= 4 {
            break;
        }
        assert!(
            std::time::Instant::now() < deadline,
            "worker received only {received}/4 requests within 10 s — request lost before drain"
        );
        std::thread::sleep(Duration::from_millis(2));
    }
    router.shutdown();
    for j in joins {
        let (c, pts, resp) = j.join().expect("drained client panicked");
        let (want_phi, want_lphi) = direct.expect(&pts, 2, 3);
        assert_eq!(resp.phi, want_phi, "client {c} phi after drain");
        assert_eq!(resp.lphi, want_lphi, "client {c} L[φ] after drain");
    }
}

#[test]
fn unknown_model_is_an_error() {
    let mut rng = Xoshiro256::new(0xE44);
    let n = 3;
    let graph = mlp_graph(&random_layers(&[n, 5, 1], &mut rng), Act::Tanh);
    let op = Operator::from_spec(CoeffSpec::EllipticGram { n, rank: n, seed: 1 });
    let mut router = Router::new();
    router.register(
        "only",
        ModelServer::spawn_dof(graph, op.dof_engine(), policy(), Pool::new(1), 2),
    );
    assert!(router.client("missing").is_err());
    assert!(router.eval_blocking("missing", vec![0.0; 3]).is_err());
    assert_eq!(router.models(), vec!["only"]);
    router.shutdown();
}
