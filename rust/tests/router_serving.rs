//! Differential battery for the multi-model serving [`Router`]:
//!
//! * **Routing is arithmetic-free** — mixed DOF / Hessian-baseline / jet
//!   traffic routed through the router returns **bitwise-identical** f32
//!   results to calling each engine directly (same f32→f64→f32 casts, same
//!   cached compiled programs; batching composition cannot matter because
//!   per-row arithmetic never mixes rows).
//! * **Metrics are exact** — dispatched/completed counters equal the
//!   number of requests sent per model, queue depth returns to zero, and
//!   the per-model server snapshots account for every request.
//! * **Shutdown drains** — requests parked in a worker's batcher when
//!   shutdown is requested are flushed and answered; no request is lost.
//! * **Drain under failure** — shutdown still answers everything when a
//!   replica is quarantined, when parked requests are failover *retries*,
//!   or when admission control is actively shedding; the
//!   dispatched/completed/failed/shed/retry counters are asserted exactly.
//! * **Pool-width independence** — routed results are bitwise identical
//!   across worker pools of 1/2/4/8 threads (shard boundaries are a
//!   function of batch size only — the determinism contract).
//!
//! `DOF_ROUTER_REQUESTS` scales the per-model traffic (the weekly
//! `fuzz-extended` CI job runs a soak-sized count).

use std::sync::Arc;
use std::time::Duration;

use anyhow::anyhow;
use dof::autodiff::{DofEngine, HessianEngine};
use dof::coordinator::{
    BatchFn, BatchPolicy, HealthPolicy, HealthState, ModelServer, Router, RouterClient,
    RouterConfig, ServeConfig, ServeError,
};
use dof::graph::{builder::random_layers, mlp_graph, Act, Graph};
use dof::jet::JetEngine;
use dof::operators::{CoeffSpec, HigherOrderOperator, HigherOrderSpec, Operator};
use dof::parallel::Pool;
use dof::tensor::Tensor;
use dof::util::Xoshiro256;

fn requests_per_model() -> usize {
    std::env::var("DOF_ROUTER_REQUESTS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(24)
}

fn policy() -> BatchPolicy {
    BatchPolicy {
        capacity: 8,
        max_wait: Duration::from_millis(1),
        max_wait_ticks: None,
    }
}

/// Deterministic f32 request points for `(model_tag, client, iter)`.
fn points(model_tag: u64, client: usize, iter: usize, rows: usize, width: usize) -> Vec<f32> {
    let mut rng = Xoshiro256::new(
        0xB00 ^ model_tag.wrapping_mul(0x9E37_79B9) ^ ((client as u64) << 32) ^ iter as u64,
    );
    (0..rows * width).map(|_| rng.normal() as f32).collect()
}

/// The serving cast: f32 points → f64 tensor (exact), engine output → f32.
fn to_tensor(pts: &[f32], rows: usize, width: usize) -> Tensor {
    Tensor::from_vec(
        &[rows, width],
        pts.iter().map(|&v| v as f64).collect::<Vec<f64>>(),
    )
}

fn cast32(t: &Tensor) -> Vec<f32> {
    t.data().iter().map(|&v| v as f32).collect()
}

/// Direct (router-free) expectation for one request against one engine.
enum Direct {
    Dof(Operator, Graph),
    Hessian(Operator, Graph),
    Jet(HigherOrderOperator, Graph),
}

impl Direct {
    fn expect(&self, pts: &[f32], rows: usize, width: usize) -> (Vec<f32>, Vec<f32>) {
        let x = to_tensor(pts, rows, width);
        match self {
            Direct::Dof(op, g) => {
                let r = op.dof_engine().compute(g, &x);
                (cast32(&r.values), cast32(&r.operator_values))
            }
            Direct::Hessian(op, g) => {
                let r = op.hessian_engine().compute(g, &x);
                (cast32(&r.values), cast32(&r.operator_values))
            }
            Direct::Jet(op, g) => {
                let r = op.jet_engine().compute(g, &x);
                (cast32(&r.values), cast32(&r.operator_values))
            }
        }
    }
}

#[test]
fn mixed_traffic_bitwise_equals_direct_engine_calls() {
    let mut rng = Xoshiro256::new(0x5EED);

    // DOF model.
    let n_dof = 4;
    let g_dof = mlp_graph(&random_layers(&[n_dof, 9, 1], &mut rng), Act::Tanh);
    let op_dof = Operator::from_spec(CoeffSpec::EllipticGram {
        n: n_dof,
        rank: n_dof,
        seed: 21,
    });
    // Hessian-baseline model (its own graph — mixed models, mixed widths).
    let n_hes = 5;
    let g_hes = mlp_graph(&random_layers(&[n_hes, 8, 1], &mut rng), Act::Sin);
    let op_hes = Operator::from_spec(CoeffSpec::EllipticGram {
        n: n_hes,
        rank: n_hes,
        seed: 22,
    });
    // Jet model (order-4 biharmonic).
    let n_jet = 3;
    let g_jet = mlp_graph(&random_layers(&[n_jet, 7, 1], &mut rng), Act::Tanh);
    let op_jet = HigherOrderOperator::from_spec(HigherOrderSpec::Biharmonic { d: n_jet });

    let mut router = Router::new();
    router.register(
        "dof",
        ModelServer::spawn_dof(g_dof.clone(), op_dof.dof_engine(), policy(), Pool::new(2), 2),
    );
    router.register(
        "hessian",
        ModelServer::spawn_hessian(
            g_hes.clone(),
            op_hes.hessian_engine(),
            policy(),
            Pool::new(2),
            2,
        ),
    );
    router.register(
        "jet",
        ModelServer::spawn_jet(g_jet.clone(), op_jet.jet_engine(), policy(), Pool::new(2), 2),
    );

    let models: Vec<(u64, RouterClient, Arc<Direct>)> = vec![
        (1, router.client("dof").unwrap(), Arc::new(Direct::Dof(op_dof, g_dof))),
        (
            2,
            router.client("hessian").unwrap(),
            Arc::new(Direct::Hessian(op_hes, g_hes)),
        ),
        (3, router.client("jet").unwrap(), Arc::new(Direct::Jet(op_jet, g_jet))),
    ];

    // Mixed traffic: 3 client threads per model, interleaved submissions,
    // varying request sizes (1..=4 rows, crossing batch boundaries).
    let clients_per_model = 3;
    let per_client = (requests_per_model() / clients_per_model).max(1);
    let mut joins = Vec::new();
    for (tag, client, direct) in &models {
        for c in 0..clients_per_model {
            let tag = *tag;
            let client = client.clone();
            let direct = Arc::clone(direct);
            joins.push(std::thread::spawn(move || {
                let width = client.width();
                for it in 0..per_client {
                    let rows = 1 + (it + c) % 4;
                    let pts = points(tag, c, it, rows, width);
                    let resp = client.eval_blocking(pts.clone()).unwrap();
                    let (want_phi, want_lphi) = direct.expect(&pts, rows, width);
                    assert_eq!(resp.phi, want_phi, "model {tag} phi (bitwise)");
                    assert_eq!(resp.lphi, want_lphi, "model {tag} L[φ] (bitwise)");
                }
            }));
        }
    }
    for j in joins {
        j.join().expect("client thread panicked");
    }

    // Exact metrics: every model saw exactly clients_per_model × per_client
    // dispatches, all completed, none failed, queue drained.
    let sent = (clients_per_model * per_client) as u64;
    for m in router.snapshot() {
        assert_eq!(m.dispatched, sent, "model {} dispatched", m.model);
        assert_eq!(m.completed, sent, "model {} completed", m.model);
        assert_eq!(m.failed, 0, "model {} failed", m.model);
        assert_eq!(m.queue_depth, 0, "model {} queue drained", m.model);
        assert!(m.peak_queue_depth >= 1, "model {} saw traffic", m.model);
        assert_eq!(m.server.requests, sent, "model {} server requests", m.model);
    }
    router.shutdown();
}

#[test]
fn shutdown_drains_queued_requests_without_loss() {
    // A long max_wait parks requests in the batcher until shutdown cuts
    // the partial batch — the drain path under test.
    let mut rng = Xoshiro256::new(0xD3A1);
    let n = 3;
    let graph = mlp_graph(&random_layers(&[n, 6, 1], &mut rng), Act::Tanh);
    let op = Operator::from_spec(CoeffSpec::EllipticGram { n, rank: n, seed: 7 });
    let mut router = Router::new();
    router.register(
        "dof",
        ModelServer::spawn_dof(
            graph.clone(),
            op.dof_engine(),
            BatchPolicy {
                capacity: 64,
                max_wait: Duration::from_secs(30),
                max_wait_ticks: None,
            },
            Pool::new(2),
            2,
        ),
    );
    let client = router.client("dof").unwrap();
    let direct = Direct::Dof(op, graph);
    let joins: Vec<_> = (0..4)
        .map(|c| {
            let client = client.clone();
            std::thread::spawn(move || {
                let width = client.width();
                let pts = points(9, c, 0, 2, width);
                let resp = client.eval_blocking(pts.clone()).unwrap();
                (c, pts, resp)
            })
        })
        .collect();
    // Wait until the worker has *received* all four requests (the
    // race-free arrival counter: a request is counted after it is pulled
    // off the channel, so Shutdown — sent strictly afterwards — cannot
    // overtake any of them). They cannot complete on their own: capacity
    // 64 is never filled and the deadline is 30 s away. Bounded wait: a
    // lost request must fail loudly here, not hang CI.
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    loop {
        let received = router.snapshot()[0].server.received;
        if received >= 4 {
            break;
        }
        assert!(
            std::time::Instant::now() < deadline,
            "worker received only {received}/4 requests within 10 s — request lost before drain"
        );
        std::thread::sleep(Duration::from_millis(2));
    }
    router.shutdown();
    for j in joins {
        let (c, pts, resp) = j.join().expect("drained client panicked");
        let (want_phi, want_lphi) = direct.expect(&pts, 2, 3);
        assert_eq!(resp.phi, want_phi, "client {c} phi after drain");
        assert_eq!(resp.lphi, want_lphi, "client {c} L[φ] after drain");
    }
}

/// Bounded poll for a router-observable condition; panics (instead of
/// hanging CI) if it never holds.
fn wait_for(router: &Router, what: &str, cond: impl Fn(&Router) -> bool) {
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    while !cond(router) {
        assert!(
            std::time::Instant::now() < deadline,
            "condition not reached within 10 s: {what}"
        );
        std::thread::sleep(Duration::from_millis(2));
    }
}

fn failing_server(width: usize, label: &str) -> ModelServer {
    let compute: BatchFn = Box::new(|_, _| Err(anyhow!("replica exploded")));
    ModelServer::spawn_cfg(width, policy(), ServeConfig::labeled(label), compute)
}

fn doubling_server(width: usize, batch: BatchPolicy, cfg: ServeConfig) -> ModelServer {
    let compute: BatchFn = Box::new(|data: &[f32], w: usize| {
        let rows = data.len() / w;
        let mut phi = Vec::with_capacity(rows);
        let mut lphi = Vec::with_capacity(rows);
        for r in 0..rows {
            let s: f32 = data[r * w..(r + 1) * w].iter().sum();
            phi.push(s);
            lphi.push(2.0 * s);
        }
        Ok((phi, lphi))
    });
    ModelServer::spawn_cfg(width, batch, cfg, compute)
}

/// Drain while a replica is quarantined: a failing replica 0 walks to
/// quarantine, live traffic fails over to replica 1 (a real DOF engine),
/// and shutdown still answers a request parked in replica 1's batcher —
/// bitwise-equal to the direct engine call, with exact counters.
#[test]
fn shutdown_drains_while_a_replica_is_quarantined() {
    let mut rng = Xoshiro256::new(0x0DA);
    let n = 3;
    let graph = mlp_graph(&random_layers(&[n, 6, 1], &mut rng), Act::Tanh);
    let op = Operator::from_spec(CoeffSpec::EllipticGram { n, rank: n, seed: 9 });
    let mut router = Router::with_config(RouterConfig {
        retries: 1,
        health: HealthPolicy {
            degrade_after: 1,
            quarantine_after: 2,
            probe_after_ticks: 8,
            probe_successes: 1,
        },
        ..RouterConfig::default()
    });
    router.register("dof", failing_server(n, "dof"));
    // Capacity 2 rows: a 2-row request cuts (and completes) immediately; a
    // 1-row request parks until a second row — or shutdown — arrives.
    router
        .add_replica(
            "dof",
            ModelServer::spawn_dof(
                graph.clone(),
                op.dof_engine(),
                BatchPolicy {
                    capacity: 2,
                    max_wait: Duration::from_secs(30),
                    max_wait_ticks: None,
                },
                Pool::new(2),
                2,
            ),
        )
        .unwrap();
    let client = router.client("dof").unwrap();
    let direct = Direct::Dof(op, graph);

    // Serial phase: two 2-row requests. Each faults on replica 0 first
    // (least-inflight pick, lowest index on the tie) and fails over; after
    // the second, replica 0 is quarantined.
    for it in 0..2 {
        let pts = points(11, 0, it, 2, n);
        let resp = client.eval_blocking(pts.clone()).unwrap();
        let (want_phi, want_lphi) = direct.expect(&pts, 2, n);
        assert_eq!(resp.phi, want_phi, "failover response not bitwise (it {it})");
        assert_eq!(resp.lphi, want_lphi);
    }
    {
        let m = &router.snapshot()[0];
        assert_eq!(m.replicas[0].state, HealthState::Quarantined);
        assert_eq!(m.quarantine_events, 1);
        assert_eq!((m.retries, m.engine_faults), (2, 2));
    }

    // Concurrent phase: three 1-row requests routed straight to replica 1
    // (replica 0 is gated). Two pair into a full batch and complete; one
    // parks until shutdown drains it.
    let joins: Vec<_> = (0..3)
        .map(|c| {
            let client = client.clone();
            std::thread::spawn(move || {
                let width = client.width();
                let pts = points(12, c, 0, 1, width);
                let resp = client.eval_blocking(pts.clone()).unwrap();
                (pts, resp)
            })
        })
        .collect();
    wait_for(&router, "replica 1 received all 5 requests, 1 parked", |r| {
        let m = &r.snapshot()[0];
        m.replicas[1].server.received >= 5 && m.queue_depth == 1
    });
    {
        let m = &router.snapshot()[0];
        assert_eq!((m.dispatched, m.completed, m.failed), (5, 4, 0));
        assert_eq!(m.queue_depth, 1, "exactly the parked request in flight");
        assert_eq!(m.retries, 2, "gated replica burned no retry budget");
        assert_eq!(m.engine_faults, 2);
        assert_eq!(m.replicas[0].state, HealthState::Quarantined);
        assert_eq!(
            (m.replicas[0].attempts, m.replicas[0].failed),
            (2, 2),
            "no traffic reached the quarantined replica"
        );
        // The per-model `server` snapshot aggregates *all* replicas, not
        // replica 0 alone: the quarantined replica's engine faults and the
        // healthy replica's completions both surface in it.
        assert_eq!(
            m.server.received,
            m.replicas.iter().map(|r| r.server.received).sum::<u64>(),
            "aggregate received sums the replica set"
        );
        assert_eq!(m.server.engine_faults, 2, "replica 0's faults in the aggregate");
        assert_eq!(m.server.requests, 4, "replica 1's completions in the aggregate");
    }
    router.shutdown();
    for j in joins {
        let (pts, resp) = j.join().expect("drained client panicked");
        let (want_phi, want_lphi) = direct.expect(&pts, 1, n);
        assert_eq!(resp.phi, want_phi, "drained response not bitwise");
        assert_eq!(resp.lphi, want_lphi);
    }
}

/// Drain with retries in flight: both parked requests are on their
/// *failover attempt* (replica 0 already failed them) when shutdown hits —
/// the drain must answer the retry attempts, and every counter is exact.
#[test]
fn shutdown_drains_retries_in_flight() {
    let mut router = Router::with_config(RouterConfig {
        retries: 1,
        ..RouterConfig::default()
    });
    router.register("m", failing_server(1, "m"));
    router
        .add_replica(
            "m",
            doubling_server(
                1,
                BatchPolicy {
                    capacity: 64,
                    max_wait: Duration::from_secs(30),
                    max_wait_ticks: None,
                },
                ServeConfig::labeled("m"),
            ),
        )
        .unwrap();
    let client = router.client("m").unwrap();

    // Submit sequentially so each request deterministically tries replica 0
    // first (least-inflight pick: the prior request is parked at replica 1,
    // so replica 0's depth 0 wins the tie-free comparison).
    let mut joins = Vec::new();
    for i in 0..2u64 {
        let c = client.clone();
        joins.push(std::thread::spawn(move || {
            c.eval_blocking(vec![i as f32 + 2.0])
        }));
        let want = i + 1;
        wait_for(&router, "retry parked at replica 1", move |r| {
            let m = &r.snapshot()[0];
            m.replicas[0].failed == want && m.replicas[1].server.received == want
        });
    }
    {
        let m = &router.snapshot()[0];
        assert_eq!((m.dispatched, m.completed, m.failed), (2, 0, 0));
        assert_eq!(m.queue_depth, 2, "both requests mid-retry");
        assert_eq!((m.retries, m.engine_faults), (2, 2));
        assert_eq!((m.replicas[0].attempts, m.replicas[0].failed), (2, 2));
        assert_eq!(m.replicas[0].state, HealthState::Degraded);
        assert_eq!(m.replicas[1].attempts, 2);
    }
    router.shutdown();
    for (i, j) in joins.into_iter().enumerate() {
        let resp = j.join().expect("client panicked").expect("retry lost in drain");
        let v = i as f32 + 2.0;
        assert_eq!((resp.phi, resp.lphi), (vec![v], vec![2.0 * v]));
    }
}

/// Admission-control shed accounting is exact, and shutdown drains the
/// admitted request that caused the overload.
#[test]
fn shed_requests_are_counted_exactly_and_drain_completes() {
    let mut router = Router::with_config(RouterConfig {
        retries: 1,
        ..RouterConfig::default()
    });
    router.register(
        "m",
        doubling_server(
            1,
            BatchPolicy {
                capacity: 64,
                max_wait: Duration::from_secs(30),
                max_wait_ticks: None,
            },
            ServeConfig {
                queue_cap: 1,
                ..ServeConfig::labeled("m")
            },
        ),
    );
    let client = router.client("m").unwrap();
    let parked = {
        let c = client.clone();
        std::thread::spawn(move || c.eval_blocking(vec![5.0]))
    };
    wait_for(&router, "parked request admitted", |r| {
        let m = &r.snapshot()[0];
        m.replicas[0].inflight == 1 && m.replicas[0].server.received == 1
    });
    // The queue is at cap: this request is shed on both attempts.
    let err = client.eval_blocking(vec![9.0]).unwrap_err();
    match &err {
        ServeError::Overloaded { model, reason } => {
            assert_eq!(model, "m");
            assert!(reason.contains("cap 1"), "{reason}");
        }
        other => panic!("expected Overloaded, got {other}"),
    }
    {
        let m = &router.snapshot()[0];
        assert_eq!((m.dispatched, m.completed, m.failed), (2, 0, 1));
        assert_eq!(m.shed, 1, "final-error classification: one shed request");
        assert_eq!(m.retries, 1, "one failover attempt, also shed");
        assert_eq!(m.engine_faults, 0);
        assert_eq!(m.replicas[0].attempts, 3, "1 parked + 2 shed attempts");
        assert_eq!(m.replicas[0].server.shed, 2, "server counts shed per attempt");
        assert_eq!(m.replicas[0].server.accepted, 1);
        assert_eq!(
            m.replicas[0].state,
            HealthState::Healthy,
            "shedding is healthy behaviour, not an engine fault"
        );
    }
    router.shutdown();
    let resp = parked.join().expect("client panicked").expect("admitted request lost");
    assert_eq!((resp.phi, resp.lphi), (vec![5.0], vec![10.0]));
}

/// Routed results are bitwise identical across pool widths 1/2/4/8: shard
/// boundaries depend on batch size only, never on worker count.
#[test]
fn routed_results_bitwise_identical_across_pool_widths() {
    let mut rng = Xoshiro256::new(0xA11);
    let n = 4;
    let graph = mlp_graph(&random_layers(&[n, 8, 1], &mut rng), Act::Tanh);
    let op = Operator::from_spec(CoeffSpec::EllipticGram { n, rank: n, seed: 31 });
    let mut baseline: Option<Vec<(Vec<f32>, Vec<f32>)>> = None;
    for threads in [1usize, 2, 4, 8] {
        let mut router = Router::new();
        router.register(
            "dof",
            ModelServer::spawn_dof(graph.clone(), op.dof_engine(), policy(), Pool::new(threads), 2),
        );
        let client = router.client("dof").unwrap();
        let mut got = Vec::new();
        for it in 0..6 {
            let rows = 1 + it % 4;
            // Same points regardless of pool width.
            let pts = points(40, 0, it, rows, n);
            let resp = client.eval_blocking(pts).unwrap();
            got.push((resp.phi, resp.lphi));
        }
        router.shutdown();
        match &baseline {
            None => baseline = Some(got),
            Some(b) => assert_eq!(b, &got, "pool width {threads} diverged bitwise"),
        }
    }
}

#[test]
fn unknown_model_is_an_error() {
    let mut rng = Xoshiro256::new(0xE44);
    let n = 3;
    let graph = mlp_graph(&random_layers(&[n, 5, 1], &mut rng), Act::Tanh);
    let op = Operator::from_spec(CoeffSpec::EllipticGram { n, rank: n, seed: 1 });
    let mut router = Router::new();
    router.register(
        "only",
        ModelServer::spawn_dof(graph, op.dof_engine(), policy(), Pool::new(1), 2),
    );
    assert!(router.client("missing").is_err());
    assert!(router.eval_blocking("missing", vec![0.0; 3]).is_err());
    assert_eq!(router.models(), vec!["only"]);
    router.shutdown();
}
