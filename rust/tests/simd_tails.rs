//! SIMD-tail equivalence suite: the chunked lane helpers and the planned
//! GEMM forms must be **bit-identical** to their retained scalar references
//! at awkward (non-multiple-of-[`dof::tensor::lanes::LANES`]) lengths — the
//! shapes where a vectorized rewrite classically diverges in its remainder
//! handling.
//!
//! Three levels, mirroring the oracle hierarchy:
//!
//! 1. **helper level** — every `tensor::lanes` helper vs its `lanes::scalar`
//!    twin, and every planned NT-GEMM form (dot / AXPY / packed AXPY) vs
//!    the dot reference, at seeded random lengths straddling the lane width;
//! 2. **engine level** — planned slab executors vs the reference
//!    interpreters, bitwise, at widths 1/3/5/7/9, batch 1, tangent width
//!    `t = 1` (rank-1 operator), plus non-multiple-of-8 Hessian widths —
//!    and across the seeded `prop::generator` architecture families;
//! 3. **thread level** — the same odd-width fixtures sharded across
//!    1/2/4/8 threads stay bit-identical (the lane rewrite must not have
//!    introduced any thread-count-dependent operation order).

use dof::autodiff::{DofEngine, HessianEngine, TangentArena};
use dof::graph::{builder::random_layers, mlp_graph, Act};
use dof::jet::{terms_from_symmetric, DirectionBasis, JetEngine};
use dof::parallel::Pool;
use dof::prop::generator::random_operator_case;
use dof::prop::{run_prop, PropResult};
use dof::tensor::lanes::{self, scalar, LANES};
use dof::tensor::{matmul_nt_dot, matmul_nt_planned, GemmForm, GemmPlan, PackedPanel, Tensor};
use dof::util::Xoshiro256;

fn randv(rng: &mut Xoshiro256, n: usize) -> Vec<f64> {
    (0..n).map(|_| rng.normal()).collect()
}

/// Every lane helper vs its scalar twin, bitwise, at seeded random lengths
/// biased toward the tail region around multiples of the lane width.
#[test]
fn lane_helpers_bitwise_match_scalar_twins() {
    run_prop("lane helpers vs scalar twins", 120, 0x5EED_7A11, |g| {
        // Lengths 0..=LANES*4+1, including every straddle of the lane edge.
        let n = g.usize_in(0, LANES * 4 + 1);
        let a = randv(g.rng(), n);
        let b = randv(g.rng(), n);
        let c = randv(g.rng(), n);
        let e = randv(g.rng(), n);
        let seed = randv(g.rng(), n);
        let k = g.rng().normal();

        let mut got = seed.clone();
        let mut want = seed.clone();
        let check = |name: &str, got: &[f64], want: &[f64]| -> PropResult {
            if got != want {
                return Err(format!("{name} diverges from scalar twin at n={n}"));
            }
            Ok(())
        };

        macro_rules! pair {
            ($name:ident, $($arg:expr),*) => {{
                got.copy_from_slice(&seed);
                want.copy_from_slice(&seed);
                lanes::$name(&mut got, $($arg),*);
                scalar::$name(&mut want, $($arg),*);
                check(stringify!($name), &got, &want)?;
            }};
        }

        pair!(add_into, &a, &b);
        pair!(sub_into, &a, &b);
        pair!(mul_into, &a, &b);
        pair!(scale_into, &a, k);
        pair!(add_assign, &a);
        pair!(mul_assign, &a);
        pair!(axpy, k, &a);
        pair!(mul_acc, &a, &b);
        pair!(scaled_mul_acc, k, &a, &b);
        pair!(scaled_sq_acc, k, &a);
        pair!(mul_mul_add_into, &a, &b, &c, &e);
        Ok(())
    });
}

/// Every planned NT-GEMM form — dot, ad-hoc-transpose AXPY, packed-panel
/// AXPY, parallel-eligible or not — agrees bitwise with the dot reference
/// at seeded shapes straddling the 4-row/4-column micro-kernels and the
/// lane width.
#[test]
fn planned_gemm_forms_bitwise_identical_at_awkward_shapes() {
    run_prop("planned GEMM forms bitwise", 80, 0x6E44_0075, |g| {
        let m = g.usize_in(1, 41);
        let k = g.usize_in(1, 19);
        let n = g.usize_in(1, 23);
        let a = randv(g.rng(), m * k);
        let b = randv(g.rng(), n * k);
        let mut want = vec![0.0; m * n];
        matmul_nt_dot(&a, &b, &mut want, m, k, n);

        let panel = PackedPanel::pack(&b, k, n);
        let plans = [
            (GemmForm::Dot, false, false),
            (GemmForm::PackedAxpy, false, false),
            (GemmForm::PackedAxpy, true, false),
            (GemmForm::PackedAxpy, false, true),
            (GemmForm::PackedAxpy, true, true),
        ];
        for (form, parallel, packed) in plans {
            let plan = GemmPlan { form, parallel };
            let pp = if packed { Some(&panel) } else { None };
            let mut got = vec![0.0; m * n];
            matmul_nt_planned(&a, &b, pp, plan, &mut got, m, k, n);
            if got != want {
                return Err(format!(
                    "form={form:?} parallel={parallel} packed={packed} \
                     diverges at m={m} k={k} n={n}"
                ));
            }
        }
        Ok(())
    });
}

/// DOF planned executor ≡ reference interpreter, bitwise, at hidden widths
/// 1/3/5/7/9, batch 1, tangent width `t = 1` (rank-1 coefficient matrix) —
/// the minimal shapes where every chunked sweep is pure scalar tail.
#[test]
fn dof_planned_bitwise_at_odd_widths_batch1_t1() {
    let mut rng = Xoshiro256::new(0x0DD5);
    for d in [1usize, 3, 5, 7, 9] {
        let n = 3;
        let g = mlp_graph(&random_layers(&[n, d, d, 1], &mut rng), Act::Tanh);
        let x = Tensor::randn(&[1, n], &mut rng).scale(0.5);
        // Exactly rank-1 coefficient matrix (single diagonal entry) → a
        // single tangent direction, `L[φ] = 1.5·∂²₀₀φ`.
        let mut a = Tensor::zeros(&[n, n]);
        a.set(0, 0, 1.5);
        let eng = DofEngine::new(&a);
        assert_eq!(eng.rank(), 1, "rank-1 A must give t=1 (width {d})");
        let planned = eng.compute(&g, &x);
        let interp = eng.compute_with_arena(&g, &x, &mut TangentArena::new());
        assert_eq!(planned.values, interp.values, "values (width {d})");
        assert_eq!(
            planned.operator_values, interp.operator_values,
            "L[φ] (width {d})"
        );
        assert_eq!(
            planned.out_tangent.data, interp.out_tangent.data,
            "tangent (width {d})"
        );
        assert_eq!(planned.cost, interp.cost, "cost (width {d})");
        assert_eq!(
            planned.peak_tangent_bytes, interp.peak_tangent_bytes,
            "peak (width {d})"
        );
    }
}

/// Program-scheduled Hessian ≡ reference path, bitwise, at
/// non-multiple-of-8 tangent widths (`N` = 5/7/9 is the Jacobian sweep's
/// per-item row count, so every GEMM and lane sweep carries a tail).
#[test]
fn hessian_planned_bitwise_at_non_multiple_of_8_widths() {
    let mut rng = Xoshiro256::new(0x4E55);
    for n in [5usize, 7, 9] {
        let g = mlp_graph(&random_layers(&[n, 9, 7, 1], &mut rng), Act::Sin);
        let x = Tensor::randn(&[3, n], &mut rng).scale(0.5);
        let b = Tensor::randn(&[n, n], &mut rng);
        let a = b.add(&b.transpose()).scale(0.5);
        let eng = HessianEngine::new(&a);
        let planned = eng.compute(&g, &x);
        let reference = eng.compute_reference(&g, &x);
        assert_eq!(planned.values, reference.values, "values (N={n})");
        assert_eq!(planned.gradient, reference.gradient, "gradient (N={n})");
        assert_eq!(planned.hessian, reference.hessian, "Hessian (N={n})");
        assert_eq!(
            planned.operator_values, reference.operator_values,
            "L[φ] (N={n})"
        );
        assert_eq!(planned.cost, reference.cost, "cost (N={n})");
        assert_eq!(
            planned.peak_tangent_bytes, reference.peak_tangent_bytes,
            "peak (N={n})"
        );
    }
}

/// The seeded `prop::generator` architecture families (MLP, sparse-product,
/// add-branches, concat-head) stay bitwise planned ≡ interpreter under the
/// chunked kernels — all three engines.
#[test]
fn generator_families_planned_bitwise_under_chunked_kernels() {
    run_prop("generator families, chunked kernels", 40, 0x7A11_FA4, |g| {
        let case = random_operator_case(g);
        let what = case.family;

        let eng = DofEngine::new(&case.a).with_lower_order(case.b.clone(), case.c);
        let planned = eng.compute(&case.graph, &case.x);
        let interp = eng.compute_with_arena(&case.graph, &case.x, &mut TangentArena::new());
        if planned.values != interp.values
            || planned.operator_values != interp.operator_values
            || planned.out_tangent.data != interp.out_tangent.data
        {
            return Err(format!("{what}: dof planned vs interpreter diverged"));
        }

        let hes = HessianEngine::new(&case.a).with_lower_order(case.b.clone(), case.c);
        let hp = hes.compute(&case.graph, &case.x);
        let hr = hes.compute_reference(&case.graph, &case.x);
        if hp.values != hr.values
            || hp.hessian != hr.hessian
            || hp.operator_values != hr.operator_values
        {
            return Err(format!("{what}: hessian planned vs reference diverged"));
        }

        let basis = DirectionBasis::from_terms(
            case.n(),
            &terms_from_symmetric(&case.a),
            case.b.as_deref(),
        );
        let jeng = JetEngine::new(basis).with_constant(case.c);
        let jp = jeng.compute(&case.graph, &case.x);
        let jr = jeng.compute_with_arena(&case.graph, &case.x, &mut TangentArena::new());
        if jp.values != jr.values
            || jp.operator_values != jr.operator_values
            || jp.out_jet.data != jr.out_jet.data
        {
            return Err(format!("{what}: jet planned vs interpreter diverged"));
        }
        Ok(())
    });
}

/// Odd-width fixtures sharded across 1/2/4/8 threads: bit-identical to the
/// single-thread base and to the unsharded engines on every path (DOF,
/// Hessian, jet). Guards against any thread-count-dependent operation
/// order sneaking into the chunked kernels or the packed-panel sharing.
#[test]
fn thread_counts_bitwise_invariant_on_odd_widths() {
    let mut rng = Xoshiro256::new(0x7423_AD5);
    let n = 7;
    let g = mlp_graph(&random_layers(&[n, 33, 9, 1], &mut rng), Act::Tanh);
    // Batch with a short last shard at shard_rows = 4.
    let x = Tensor::randn(&[13, n], &mut rng).scale(0.5);
    let b = Tensor::randn(&[n, n], &mut rng);
    let a = b.add(&b.transpose()).scale(0.5);
    let shard_rows = 4;

    let dof = DofEngine::new(&a);
    let dof_full = dof.compute(&g, &x);
    let dof_base = dof.compute_sharded(&g, &x, &Pool::new(1), shard_rows);
    assert_eq!(dof_base.values, dof_full.values);
    assert_eq!(dof_base.operator_values, dof_full.operator_values);

    let hes = HessianEngine::new(&a);
    let hes_full = hes.compute(&g, &x);
    let hes_base = hes.compute_sharded(&g, &x, &Pool::new(1), shard_rows);
    assert_eq!(hes_base.values, hes_full.values);
    assert_eq!(hes_base.hessian, hes_full.hessian);
    assert_eq!(hes_base.operator_values, hes_full.operator_values);

    let jeng = JetEngine::new(DirectionBasis::from_terms(
        n,
        &terms_from_symmetric(&a),
        None,
    ));
    let jet_full = jeng.compute(&g, &x);
    let jet_base = jeng.compute_sharded(&g, &x, &Pool::new(1), shard_rows);
    assert_eq!(jet_base.values, jet_full.values);
    assert_eq!(jet_base.operator_values, jet_full.operator_values);

    for threads in [2usize, 4, 8] {
        let pool = Pool::new(threads);
        let d = dof.compute_sharded(&g, &x, &pool, shard_rows);
        assert_eq!(d.values, dof_base.values, "dof values at {threads} threads");
        assert_eq!(
            d.operator_values, dof_base.operator_values,
            "dof L[φ] at {threads} threads"
        );
        assert_eq!(d.cost, dof_base.cost, "dof cost at {threads} threads");

        let h = hes.compute_sharded(&g, &x, &pool, shard_rows);
        assert_eq!(h.hessian, hes_base.hessian, "hessian at {threads} threads");
        assert_eq!(
            h.operator_values, hes_base.operator_values,
            "hessian L[φ] at {threads} threads"
        );

        let j = jeng.compute_sharded(&g, &x, &pool, shard_rows);
        assert_eq!(j.values, jet_base.values, "jet values at {threads} threads");
        assert_eq!(
            j.operator_values, jet_base.operator_values,
            "jet L[φ] at {threads} threads"
        );
        assert_eq!(
            j.out_jet.data, jet_base.out_jet.data,
            "jet output at {threads} threads"
        );
    }
}
