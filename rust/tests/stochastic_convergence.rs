//! Convergence and determinism battery for the stochastic Taylor jet
//! engine (STDE):
//!
//! * **Unbiasedness, fuzzed** — over ≥50 seeded `prop::generator` operator
//!   families, the estimate lands within a few of its own reported
//!   standard errors of the exact DOF answer, for both Gaussian and
//!   sparse-Rademacher direction sampling (`E[estimate] = exact`; the
//!   reported `std_error` is the certificate).
//! * **Convergence rate** — on a fixed operator, the mean absolute error
//!   shrinks as the sample count grows (the ~1/√S law, checked end to
//!   end rather than per-point).
//! * **Determinism** — per-point direction streams are counter-derived
//!   from `(seed, global point index, sample index)`, so a fixed seed is
//!   bit-identical across 1/2/4/8 threads and every shard decomposition,
//!   and matches the unsharded path.
//! * **Variance honesty** — the engine's reported `variance / samples`
//!   tracks the empirical spread of independent estimates.
//!
//! `DOF_STDE_SAMPLES=<n>` raises the sample count (the scheduled CI job
//! uses a larger count, tightening every bound here).

use dof::autodiff::DofEngine;
use dof::graph::{Act, Graph};
use dof::jet::{terms_from_symmetric, DirectionSampling, StochasticJetEngine};
use dof::nn::{Mlp, MlpSpec};
use dof::operators::{CoeffSpec, HigherOrderOperator, HigherOrderSpec, Operator};
use dof::parallel::Pool;
use dof::prop::generator::{random_operator_case, OperatorCase};
use dof::prop::{run_prop, PropResult};
use dof::tensor::Tensor;
use dof::util::Xoshiro256;

fn stde_samples() -> u32 {
    std::env::var("DOF_STDE_SAMPLES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(256)
}

fn mlp(n: usize, seed: u64) -> Graph {
    Mlp::init(
        MlpSpec {
            in_dim: n,
            hidden: 16,
            layers: 2,
            out_dim: 1,
            act: Act::Tanh,
        },
        seed,
    )
    .to_graph()
}

fn case_engine(
    case: &OperatorCase,
    sampling: DirectionSampling,
    samples: u32,
    seed: u64,
) -> StochasticJetEngine {
    StochasticJetEngine::from_terms(
        case.n(),
        terms_from_symmetric(&case.a),
        sampling,
        samples,
        seed,
    )
    .with_lower_order(case.b.clone(), case.c)
}

/// The estimate must land within `8·std_error` (plus a floor for
/// operators whose stochastic part is ~0) of the exact value, per row.
fn assert_within_reported_error(
    exact: &Tensor,
    est: &Tensor,
    std_error: &Tensor,
    batch: usize,
    what: &str,
) -> PropResult {
    for bi in 0..batch {
        let e = exact.at(bi, 0);
        let v = est.at(bi, 0);
        let tol = 8.0 * std_error.at(bi, 0) + 1e-6 * (1.0 + e.abs());
        if (v - e).abs() > tol {
            return Err(format!(
                "{what}: row {bi}: estimate {v} vs exact {e} exceeds {tol}"
            ));
        }
    }
    Ok(())
}

/// ≥50 fuzzed operator families, both sampling laws: the estimate agrees
/// with the exact DOF engine to within its own error report, and φ (never
/// estimated) is bitwise identical.
#[test]
fn estimator_is_unbiased_over_fuzz_families() {
    let samples = stde_samples();
    run_prop("stde unbiasedness", 50, 0x57DE_0001, |g| {
        let case = random_operator_case(g);
        let exact = DofEngine::new(&case.a)
            .with_lower_order(case.b.clone(), case.c)
            .compute(&case.graph, &case.x);
        let nnz = (case.n() / 2).max(1);
        let laws = [
            ("gaussian", DirectionSampling::Gaussian),
            ("sparse", DirectionSampling::SparseRademacher { nnz }),
        ];
        for (name, sampling) in laws {
            let seed = g.rng().next_u64();
            let st = case_engine(&case, sampling, samples, seed)
                .compute(&case.graph, &case.x);
            if st.values != exact.values {
                return Err(format!("{}: {name}: φ differs bitwise", case.family));
            }
            assert_within_reported_error(
                &exact.operator_values,
                &st.operator_values,
                &st.std_error,
                case.batch(),
                &format!("{} ({name}, seed {seed})", case.family),
            )?;
        }
        Ok(())
    });
}

/// The ~1/√S law, end to end: on a fixed elliptic operator, the mean
/// absolute error over 16 points shrinks from S=8 to S=256 (a 32×
/// sample-budget increase buys ~5.7× less error; asserting a strict
/// decrease leaves many standard deviations of slack).
#[test]
fn mean_abs_error_shrinks_as_samples_grow() {
    let n = 6;
    let graph = mlp(n, 5);
    let op = Operator::from_spec(CoeffSpec::EllipticGram { n, rank: n, seed: 3 });
    let mut rng = Xoshiro256::new(17);
    let x = Tensor::randn(&[16, n], &mut rng).scale(0.5);
    let exact = op.dof_engine().compute(&graph, &x);
    let mean_abs_err = |samples: u32| -> f64 {
        let st = op
            .stochastic_engine(DirectionSampling::Gaussian, samples, 99)
            .compute(&graph, &x);
        (0..16)
            .map(|bi| (st.operator_values.at(bi, 0) - exact.operator_values.at(bi, 0)).abs())
            .sum::<f64>()
            / 16.0
    };
    let coarse = mean_abs_err(8);
    let mid = mean_abs_err(64);
    let fine = mean_abs_err(256);
    assert!(
        fine < coarse,
        "error must shrink with samples: S=8 → {coarse:.3e}, S=64 → {mid:.3e}, \
         S=256 → {fine:.3e}"
    );
    assert!(fine.is_finite() && coarse.is_finite());
}

/// The determinism contract: a fixed seed is bit-identical across thread
/// counts and shard decompositions, and every sharded run matches the
/// unsharded [`StochasticJetEngine::compute`]. Covers both the elliptic
/// (order-2) and biharmonic (order-4) paths.
#[test]
fn fixed_seed_estimates_are_thread_and_shard_invariant() {
    let elliptic_n = 5;
    let elliptic = (
        mlp(elliptic_n, 2),
        Operator::from_spec(CoeffSpec::EllipticGram {
            n: elliptic_n,
            rank: elliptic_n,
            seed: 7,
        })
        .stochastic_engine(DirectionSampling::Gaussian, 16, 42),
        elliptic_n,
    );
    let bi_d = 3;
    let biharmonic = (
        mlp(bi_d, 4),
        HigherOrderOperator::from_spec(HigherOrderSpec::Biharmonic { d: bi_d })
            .stochastic_engine(DirectionSampling::SparseRademacher { nnz: 2 }, 16, 42),
        bi_d,
    );
    for (graph, engine, n) in [elliptic, biharmonic] {
        let mut rng = Xoshiro256::new(31);
        // 11 rows: never a whole number of any shard size below.
        let x = Tensor::randn(&[11, n], &mut rng).scale(0.5);
        let base = engine.compute(&graph, &x);
        for threads in [1usize, 2, 4, 8] {
            let pool = Pool::new(threads);
            for shard_rows in [1usize, 3, 4, 5, 32] {
                let r = engine.compute_sharded(&graph, &x, &pool, shard_rows);
                assert_eq!(
                    r.operator_values, base.operator_values,
                    "estimate not invariant at {threads} threads, shard_rows {shard_rows}"
                );
                assert_eq!(r.values, base.values);
                assert_eq!(r.variance, base.variance);
                assert_eq!(r.std_error, base.std_error);
                assert_eq!(r.cost, base.cost);
                assert_eq!(r.samples, base.samples);
            }
        }
    }
}

/// Variance honesty: over 48 independent seeds, the empirical variance of
/// the estimates tracks the engine's mean reported `variance / samples`
/// (the squared standard error) within a loose constant factor.
#[test]
fn variance_report_tracks_empirical_spread() {
    let n = 4;
    let graph = mlp(n, 9);
    let op = Operator::from_spec(CoeffSpec::EllipticGram { n, rank: n, seed: 13 });
    let mut rng = Xoshiro256::new(23);
    let x = Tensor::randn(&[1, n], &mut rng).scale(0.5);
    let samples = 32u32;
    let reps = 48usize;
    let mut estimates = Vec::with_capacity(reps);
    let mut reported = 0.0;
    for seed in 0..reps as u64 {
        let st = op
            .stochastic_engine(DirectionSampling::Gaussian, samples, 1000 + seed)
            .compute(&graph, &x);
        estimates.push(st.operator_values.at(0, 0));
        reported += st.std_error.at(0, 0).powi(2);
    }
    reported /= reps as f64;
    let mean = estimates.iter().sum::<f64>() / reps as f64;
    let empirical = estimates.iter().map(|e| (e - mean).powi(2)).sum::<f64>()
        / (reps - 1) as f64;
    assert!(
        reported > 0.0 && empirical > 0.0,
        "a nontrivial operator must have nonzero estimator variance"
    );
    let ratio = empirical / reported;
    assert!(
        (0.35..=2.8).contains(&ratio),
        "empirical spread {empirical:.3e} vs reported std_error² {reported:.3e} \
         (ratio {ratio:.2}) — the variance report is dishonest"
    );
}

/// The order-4 path against its exact oracle: the biharmonic estimate
/// agrees with the exact jet engine to within its own error report.
#[test]
fn biharmonic_estimate_converges_to_exact_jet() {
    let d = 3;
    let graph = mlp(d, 6);
    let op = HigherOrderOperator::from_spec(HigherOrderSpec::Biharmonic { d });
    let mut rng = Xoshiro256::new(41);
    let x = Tensor::randn(&[2, d], &mut rng).scale(0.5);
    let exact = op.jet_engine().compute(&graph, &x);
    let samples = stde_samples().max(128);
    let st = op
        .stochastic_engine(DirectionSampling::Gaussian, samples, 77)
        .compute(&graph, &x);
    assert_eq!(st.values, exact.values, "φ is exact, never estimated");
    for bi in 0..2 {
        let e = exact.operator_values.at(bi, 0);
        let v = st.operator_values.at(bi, 0);
        let tol = 8.0 * st.std_error.at(bi, 0) + 1e-6 * (1.0 + e.abs());
        assert!(
            (v - e).abs() <= tol,
            "row {bi}: Δ²φ estimate {v} vs exact {e} exceeds {tol} ({samples} samples)"
        );
    }
}
