//! Integration: the Rust DOF/Hessian engines and the AOT XLA artifacts must
//! agree on identical weights — closing the loop
//! `rust engine (f64) == jax DOF (f32, pallas) == jax.hessian (f32)`.
//!
//! Requires `make artifacts`. Tests are skipped (not failed) when the
//! artifacts directory is absent so `cargo test` works on a fresh clone.

use dof::graph::{builder::LayerWeights, mlp_graph, Act};
use dof::nn::serialize::{entries_to_mlp, read_dofw};
use dof::operators::Operator;
use dof::runtime::{ArtifactRegistry, Executor};
use dof::tensor::Tensor;
use dof::util::Xoshiro256;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.txt").is_file() {
        Some(dir)
    } else {
        eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
        None
    }
}

/// The PJRT executor is a stub without the `pjrt` feature; tests that
/// execute artifacts must skip (not panic) on the default build even when
/// the artifacts directory exists.
fn pjrt_available() -> bool {
    if cfg!(feature = "pjrt") {
        true
    } else {
        eprintln!("skipping: built without the pjrt feature (stub executor)");
        false
    }
}

fn load_mlp(dir: &std::path::Path) -> LayerWeights {
    let entries = read_dofw(dir.join("mlp_weights.dofw")).expect("weights readable");
    entries_to_mlp(&entries)
}

fn load_coeff(dir: &std::path::Path, name: &str) -> Tensor {
    let entries = read_dofw(dir.join(format!("coeff_mlp_{name}.dofw"))).expect("coeff");
    entries[0].tensor.clone()
}

/// Engine-vs-engine on the *exported* weights (no XLA needed beyond files).
#[test]
fn rust_engines_agree_on_exported_weights() {
    let Some(dir) = artifacts_dir() else { return };
    let layers = load_mlp(&dir);
    let graph = mlp_graph(&layers, Act::Tanh);
    let mut rng = Xoshiro256::new(42);
    let x = Tensor::randn(&[4, 64], &mut rng);
    for name in ["elliptic", "lowrank", "general"] {
        let a = load_coeff(&dir, name);
        let op = Operator::from_matrix(a, name);
        let dof = op.dof_engine().compute(&graph, &x);
        let hes = op.hessian_engine().compute(&graph, &x);
        for b in 0..4 {
            let dv = dof.operator_values.at(b, 0);
            let hv = hes.operator_values.at(b, 0);
            assert!(
                (dv - hv).abs() < 1e-6 * hv.abs().max(1.0),
                "{name} b={b}: {dv} vs {hv}"
            );
        }
    }
}

/// The real cross-language check: XLA artifact vs Rust engine numerics.
#[test]
fn xla_artifacts_match_rust_engine() {
    if !pjrt_available() {
        return;
    }
    let Some(dir) = artifacts_dir() else { return };
    let reg = ArtifactRegistry::open(&dir).expect("registry");
    let mut exec = Executor::cpu().expect("PJRT cpu client");
    let layers = load_mlp(&dir);
    let graph = mlp_graph(&layers, Act::Tanh);

    let mut rng = Xoshiro256::new(7);
    let batch = reg.batch_of("dof_mlp_elliptic").unwrap_or(32);
    let xf: Vec<f32> = (0..batch * 64).map(|_| rng.normal() as f32).collect();
    let xd = Tensor::from_vec(
        &[batch, 64],
        xf.iter().map(|&v| v as f64).collect::<Vec<f64>>(),
    );

    for name in ["elliptic", "lowrank", "general"] {
        let a = load_coeff(&dir, name);
        let op = Operator::from_matrix(a, name);
        let rust = op.dof_engine().compute(&graph, &xd);

        for artifact in [format!("dof_mlp_{name}"), format!("hessian_mlp_{name}")] {
            exec.load(&artifact, &reg.path(&artifact).unwrap()).unwrap();
            let outs = exec
                .run_f32(&artifact, &[(&xf, &[batch, 64])])
                .unwrap_or_else(|e| panic!("running {artifact}: {e:#}"));
            let (phi, lphi) = (&outs[0], &outs[1]);
            assert_eq!(phi.len(), batch);
            assert_eq!(lphi.len(), batch);
            for b in 0..batch {
                let pv = rust.values.at(b, 0);
                assert!(
                    (phi[b] as f64 - pv).abs() < 1e-3 * pv.abs().max(1.0),
                    "{artifact} phi[{b}]: xla {} vs rust {pv}",
                    phi[b]
                );
                let lv = rust.operator_values.at(b, 0);
                // f32 second derivatives of an 8-layer-deep f32 graph:
                // allow 1e-2 relative.
                assert!(
                    (lphi[b] as f64 - lv).abs() < 1e-2 * lv.abs().max(1.0),
                    "{artifact} lphi[{b}]: xla {} vs rust {lv}",
                    lphi[b]
                );
            }
        }
    }
}

/// The PINN train-step artifact must produce a finite loss and a gradient
/// that decreases the loss when applied (one SGD step).
#[test]
fn pinn_step_artifact_trains() {
    if !pjrt_available() {
        return;
    }
    let Some(dir) = artifacts_dir() else { return };
    let reg = ArtifactRegistry::open(&dir).expect("registry");
    let mut exec = Executor::cpu().expect("client");
    exec.load("pinn_heat_step", &reg.path("pinn_heat_step").unwrap())
        .unwrap();

    let theta_entries = read_dofw(dir.join("pinn_heat_theta0.dofw")).unwrap();
    let mut theta: Vec<f32> = theta_entries[0]
        .tensor
        .data()
        .iter()
        .map(|&v| v as f32)
        .collect();
    let p = theta.len();

    let mut rng = Xoshiro256::new(5);
    let batch = reg.batch_of("pinn_heat_step").unwrap_or(128);
    let x: Vec<f32> = (0..batch * 3).map(|_| rng.next_f64() as f32).collect();

    let run = |exec: &Executor, theta: &[f32]| -> (f32, Vec<f32>) {
        let outs = exec
            .run_f32("pinn_heat_step", &[(theta, &[p]), (&x, &[batch, 3])])
            .expect("step runs");
        (outs[0][0], outs[1].clone())
    };
    let (loss0, grad) = run(&exec, &theta);
    assert!(loss0.is_finite() && loss0 > 0.0, "loss0 = {loss0}");
    assert_eq!(grad.len(), p);
    assert!(grad.iter().all(|g| g.is_finite()));

    // One gradient step on the same batch must reduce the loss.
    let gnorm: f32 = grad.iter().map(|g| g * g).sum::<f32>().sqrt();
    let lr = 0.05 / gnorm.max(1e-6);
    for (t, g) in theta.iter_mut().zip(&grad) {
        *t -= lr * g;
    }
    let (loss1, _) = run(&exec, &theta);
    assert!(
        loss1 < loss0,
        "gradient step should reduce loss: {loss0} -> {loss1}"
    );
}

/// Sparse-architecture artifacts: DOF (structurally sparse) vs the dense
/// Hessian artifact on identical inputs.
#[test]
fn sparse_artifacts_agree() {
    if !pjrt_available() {
        return;
    }
    let Some(dir) = artifacts_dir() else { return };
    let reg = ArtifactRegistry::open(&dir).expect("registry");
    if reg.path("hessian_sparse_general").is_err() {
        eprintln!("skipping: hessian_sparse_general not built");
        return;
    }
    let mut exec = Executor::cpu().expect("client");
    let batch = reg.batch_of("dof_sparse_general").unwrap_or(32);
    let mut rng = Xoshiro256::new(9);
    let x: Vec<f32> = (0..batch * 64)
        .map(|_| (0.4 * rng.normal()) as f32)
        .collect();
    for name in ["dof_sparse_general", "hessian_sparse_general"] {
        exec.load(name, &reg.path(name).unwrap()).unwrap();
    }
    let dof = exec
        .run_f32("dof_sparse_general", &[(&x, &[batch, 64])])
        .unwrap();
    let hes = exec
        .run_f32("hessian_sparse_general", &[(&x, &[batch, 64])])
        .unwrap();
    for b in 0..batch {
        assert!(
            (dof[0][b] - hes[0][b]).abs() < 1e-3 * hes[0][b].abs().max(1.0),
            "phi[{b}]: {} vs {}",
            dof[0][b],
            hes[0][b]
        );
        assert!(
            (dof[1][b] - hes[1][b]).abs() < 2e-2 * hes[1][b].abs().max(1.0),
            "lphi[{b}]: {} vs {}",
            dof[1][b],
            hes[1][b]
        );
    }
}
