//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The build environment has no network access and no registry cache, so the
//! real `anyhow` cannot be fetched. This vendored crate implements the small
//! surface the repository actually uses — [`Error`], [`Result`], the
//! [`anyhow!`] / [`ensure!`] macros, and the [`Context`] extension trait —
//! with the same observable semantics:
//!
//! * `Display` prints the outermost message; the alternate form (`{:#}`)
//!   prints the whole context chain joined by `": "`.
//! * `?` converts any `std::error::Error + Send + Sync + 'static` (the
//!   standard anyhow blanket conversion; `Error` itself deliberately does
//!   not implement `std::error::Error`, which is what makes the blanket
//!   `From` coherent).

use std::fmt;

/// Dynamic error: an ordered chain of messages, outermost first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Self {
            chain: vec![message.to_string()],
        }
    }

    /// Prepend a context message (the new outermost layer).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut source = e.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Self { chain }
    }
}

/// `Result` defaulted to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding context to fallible results.
pub trait Context<T> {
    /// Wrap the error with a fixed context message.
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    /// Wrap the error with a lazily-built context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error {
            chain: vec![context.to_string(), e.to_string()],
        })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error {
            chain: vec![f().to_string(), e.to_string()],
        })
    }
}

/// Construct an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)+) => {
        $crate::Error::msg(format!($fmt, $($arg)+))
    };
}

/// Return early with an error when a condition does not hold.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing thing")
    }

    #[test]
    fn display_and_alternate() {
        let e: Error = Result::<(), _>::Err(io_err())
            .with_context(|| "reading config")
            .unwrap_err();
        assert_eq!(e.to_string(), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: missing thing");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert!(e.to_string().contains("missing thing"));
    }

    #[test]
    fn macros() {
        fn guard(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            Ok(x)
        }
        assert_eq!(guard(3).unwrap(), 3);
        let e = guard(-1).unwrap_err();
        assert_eq!(e.to_string(), "x must be positive, got -1");
        let owned = anyhow!(String::from("owned message"));
        assert_eq!(owned.to_string(), "owned message");
    }
}
